"""Step functions lowered by the launcher and the dry-run.

 * ``train_step``   — loss + grad + optimizer update (SGD-momentum default,
                      the paper's optimizer; AdamW selectable).
 * ``prefill_step`` — forward over the full prompt, returns last-position
                      logits (serving prefill; no full-logit materialization).
 * ``serve_step``   — one-token decode against a KV/state cache.
 * ``mhd_train_step`` — the paper's technique on LM clients: one student
                      update with teacher predictions distilled on a public
                      batch (teacher params are explicit inputs; in the
                      multi-pod runtime they come from the checkpoint pool).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.mhd import MHDConfig
from repro.models.zoo import ModelBundle
from repro.optim.optimizers import Optimizer


def make_train_step(bundle: ModelBundle, optimizer: Optimizer) -> Callable:
    def train_step(state: Dict[str, Any], batch: Dict[str, Any]):
        def loss_fn(p):
            return bundle.loss(p, batch)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"])
        params, opt = optimizer.update(grads, state["opt"], state["params"],
                                       state["step"])
        new_state = {"params": params, "opt": opt, "step": state["step"] + 1}
        return new_state, {"loss": loss, **metrics}

    return train_step


def make_prefill_step(bundle: ModelBundle) -> Callable:
    def prefill_step(params, batch):
        out = bundle.apply(params, batch)
        return out["logits"][:, -1, :]  # next-token logits only

    return prefill_step


def make_serve_step(bundle: ModelBundle) -> Callable:
    def serve_step(params, batch):
        logits, caches = bundle.decode_step(params, batch["token"],
                                            batch["caches"])
        return logits[:, -1, :], caches

    return serve_step


def make_mhd_train_step(bundle: ModelBundle, optimizer: Optimizer,
                        mhd_cfg: MHDConfig, teacher_bundle=None) -> Callable:
    """Paper technique as one jitted step: student update from Δ teachers.

    teachers: pytree stacked over Δ of teacher params (same arch unless
    ``teacher_bundle`` given). Teacher forward runs inside the step (as in
    the co-located deployment); outputs are stop-gradiented by mhd logic.
    """
    from repro.core.lm_adapter import lm_mhd_loss, lm_mhd_outputs

    t_bundle = teacher_bundle or bundle

    def mhd_train_step(state, batch):
        private_batch = {"tokens": batch["private_tokens"]}
        public_batch = {"tokens": batch["public_tokens"]}

        def teacher_out(tp):
            o = lm_mhd_outputs(t_bundle, tp, public_batch)
            return {"embedding": o["embedding"], "logits": o["logits"],
                    "aux_logits": o["aux_logits"]}

        teachers = jax.lax.map(teacher_out, batch["teacher_params"])

        def loss_fn(p):
            return lm_mhd_loss(bundle, p, private_batch, public_batch,
                               teachers, mhd_cfg)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"])
        params, opt = optimizer.update(grads, state["opt"], state["params"],
                                       state["step"])
        new_state = {"params": params, "opt": opt, "step": state["step"] + 1}
        return new_state, {"loss": loss, **metrics}

    return mhd_train_step


def train_state_shapes(bundle: ModelBundle, optimizer: Optimizer):
    """abstract TrainState via eval_shape (no allocation)."""
    params = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
    opt = jax.eval_shape(optimizer.init, params)
    step = jax.ShapeDtypeStruct((), jnp.int32)
    return {"params": params, "opt": opt, "step": step}


def init_train_state(bundle: ModelBundle, optimizer: Optimizer, seed: int = 0):
    params = bundle.init(jax.random.PRNGKey(seed))
    return {"params": params, "opt": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32)}
