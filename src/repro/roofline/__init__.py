from repro.roofline.hlo_parse import collective_bytes_from_hlo, parse_shape_bytes
from repro.roofline.analysis import (
    V5E,
    HardwareSpec,
    RooflineReport,
    roofline_from_artifacts,
    model_flops,
    active_params,
)

__all__ = [
    "collective_bytes_from_hlo",
    "parse_shape_bytes",
    "V5E",
    "HardwareSpec",
    "RooflineReport",
    "roofline_from_artifacts",
    "model_flops",
    "active_params",
]
