"""Roofline terms from the compiled dry-run artifact (DESIGN/EXPERIMENTS
§Roofline).

All inputs are *per-device* (post-SPMD cost_analysis + HLO parsing), so:

    compute term    = device_flops / peak_flops
    memory term     = device_bytes / hbm_bw
    collective term = device_collective_bytes / ici_bw

which equals the global formulation (global / (chips × per-chip rate)).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12  # bf16 FLOP/s per chip
    hbm_bw: float = 819e9  # bytes/s per chip
    ici_bw: float = 50e9  # bytes/s per link
    hbm_bytes: float = 16e9  # capacity per chip


V5E = HardwareSpec()


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    device_flops: float
    device_bytes: float
    device_collective_bytes: float
    model_flops_global: float
    useful_flops_ratio: float  # MODEL_FLOPS / (device_flops * chips)
    device_arg_bytes: float  # params+inputs per device (memory_analysis)
    device_temp_bytes: float
    fits_hbm: bool
    note: str = ""

    def to_row(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def active_params(cfg, total_params: int) -> int:
    """Active parameter count (MoE: only top-k routed experts per token)."""
    moe = getattr(cfg, "moe", None)
    if moe is None or getattr(cfg, "family", "") not in ("moe",):
        return total_params
    # expert weights per MoE layer: 3 matrices (gate/up/down)
    n_moe_layers = 0
    for st in cfg.stages:
        for spec in st.block:
            if spec.ffn in ("moe", "moe_dense_parallel"):
                n_moe_layers += st.repeats
    per_expert = 3 * cfg.d_model * moe.d_ff_expert
    routed_total = n_moe_layers * moe.num_experts * per_expert
    routed_active = n_moe_layers * moe.top_k * per_expert
    return total_params - routed_total + routed_active


def model_flops(cfg, total_params: int, tokens: int, mode: str) -> float:
    """6·N·D (train) or 2·N·D (inference), N = active params."""
    n_active = active_params(cfg, total_params)
    mult = 6.0 if mode == "train" else 2.0
    return mult * n_active * tokens


def roofline_from_artifacts(
    arch: str,
    shape_name: str,
    mesh_name: str,
    chips: int,
    cost: Dict[str, float],
    collectives: Dict[str, int],
    memory: Optional[Dict[str, float]],
    cfg,
    total_params: int,
    tokens: int,
    mode: str,
    hw: HardwareSpec = V5E,
    note: str = "",
) -> RooflineReport:
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    coll = float(collectives.get("total", 0))

    compute_s = flops / hw.peak_flops
    memory_s = bytes_acc / hw.hbm_bw
    collective_s = coll / hw.ici_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)

    mf = model_flops(cfg, total_params, tokens, mode)
    global_flops = flops * chips
    ratio = mf / global_flops if global_flops else 0.0

    arg_b = float(memory.get("argument_size_in_bytes", 0)) if memory else 0.0
    tmp_b = float(memory.get("temp_size_in_bytes", 0)) if memory else 0.0
    out_b = float(memory.get("output_size_in_bytes", 0)) if memory else 0.0
    fits = (arg_b + tmp_b + out_b) <= hw.hbm_bytes

    return RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_name,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant,
        device_flops=flops, device_bytes=bytes_acc,
        device_collective_bytes=coll,
        model_flops_global=mf, useful_flops_ratio=ratio,
        device_arg_bytes=arg_b, device_temp_bytes=tmp_b,
        fits_hbm=fits, note=note,
    )


def format_table(reports) -> str:
    hdr = (f"{'arch':<22} {'shape':<12} {'mesh':<9} {'compute_s':>10} "
           f"{'memory_s':>10} {'coll_s':>10} {'dominant':>10} "
           f"{'6ND/HLO':>8} {'fits':>5}")
    lines = [hdr, "-" * len(hdr)]
    for r in reports:
        lines.append(
            f"{r.arch:<22} {r.shape:<12} {r.mesh:<9} {r.compute_s:>10.4f} "
            f"{r.memory_s:>10.4f} {r.collective_s:>10.4f} {r.dominant:>10} "
            f"{r.useful_flops_ratio:>8.3f} {str(r.fits_hbm):>5}")
    return "\n".join(lines)
