"""Extract per-device collective traffic from post-SPMD HLO text.

``cost_analysis()`` does not expose collective bytes, so we parse
``compiled.as_text()`` and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(including their -start async forms). Shapes in the per-device module are
already shard-local, so the sums are bytes moved per device.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e5m2": 1, "f8e4m3fn": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g.  bf16[256,1024]{1,0}   f32[]   (tuples handled by iterating matches)
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
# result assignment:  %name = <shape-or-tuple> <opname>(operands...)
_INSTR_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"([a-z0-9-]+)\(([^)]*(?:\([^)]*\))?[^)]*)\)"
)


def parse_shape_bytes(text: str) -> int:
    """Sum bytes of every typed shape literal appearing in ``text``."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind operand bytes (per device). Keys: op kind + 'total'."""
    out: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        result_part, opname, operands = m.groups()
        kind = None
        for c in _COLLECTIVES:
            if opname == c or opname == c + "-start":
                kind = c
                break
        if kind is None:
            continue
        nbytes = parse_shape_bytes(operands)
        if nbytes == 0:
            # operands printed without inline types; fall back to result shape
            nbytes = parse_shape_bytes(result_part)
        out[kind] += nbytes
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return dict(out)


def count_ops(hlo_text: str, opnames=("fusion", "custom-call")) -> Dict[str, int]:
    """Rough op histogram — used to spot remat-duplicated compute in §Perf."""
    counts: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if m:
            counts[m.group(2)] += 1
    return dict(counts)
