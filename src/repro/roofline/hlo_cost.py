"""Loop-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts while-loop *bodies once* — for a
scan-over-layers model that understates FLOPs by ~num_layers×. This module
re-derives per-device roofline inputs from ``compiled.as_text()``:

  * FLOPs: every ``dot`` (2 · prod(result) · prod(lhs contracting dims)),
    including dots inside fusions, multiplied up through while-loop trip
    counts (XLA prints ``backend_config={"known_trip_count":{"n":...}}``).
  * HBM bytes: fusion-boundary traffic — each scheduled instruction reads
    its operands and writes its result; fusion-internal ops stay in
    registers/VMEM and are not counted. dynamic-update-slice counts the
    update slice (in-place aliasing), not the full buffer.
  * Collective bytes: operand sizes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute (+ async -start forms),
    loop-multiplied.

Operand shapes are resolved through a per-computation symbol table (the
scheduled HLO prints operands as bare ``%name`` references).

Validated against closed-form expectations in tests/test_roofline.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e5m2": 1, "f8e4m3fn": 1, "f8e4m3": 1,
    "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
}

_NO_TRAFFIC_OPS = {
    "parameter", "get-tuple-element", "tuple", "constant", "bitcast",
    "after-all", "add-dependency", "partition-id", "replica-id", "iota",
}

# ops whose called computations run per-element (don't descend for bytes,
# do descend for flops — a dot inside a fused computation is real MXU work)
_FUSION_LIKE = {
    "fusion", "reduce", "reduce-window", "scatter", "map",
    "select-and-scatter", "sort",
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*"
    r"(\(.*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"([a-z0-9\-]+)\((.*)$")
_COMP_START_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.+\{\s*$")
_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_bytes_one(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * b


def _all_shape_bytes(text: str) -> int:
    return sum(_shape_bytes_one(dt, dims) for dt, dims in _SHAPE_RE.findall(text))


def _first_shape_dims(text: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    result_text: str
    operand_text: str
    tail: str
    line: str


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * mult

    @property
    def coll_total(self) -> float:
        return sum(self.coll.values())


def _split_operands_tail(rest: str) -> Tuple[str, str]:
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rest[:i], rest[i + 1:]
    return rest, ""


def parse_computations(hlo_text: str):
    """Returns (comps: name -> [Instr], entry_name)."""
    comps: Dict[str, List[Instr]] = {}
    entry_name = None
    cur: Optional[List[Instr]] = None
    cur_name = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if cur is None:
            m = _COMP_START_RE.match(stripped)
            if m:
                cur_name = m.group(2)
                cur = []
                if m.group(1):
                    entry_name = cur_name
            continue
        if stripped == "}":
            comps[cur_name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            name, result_text, opcode, rest = m.groups()
            operands, tail = _split_operands_tail(rest)
            cur.append(Instr(name=name, opcode=opcode, result_text=result_text,
                             operand_text=operands, tail=tail, line=line))
    if cur is not None and cur_name is not None:
        comps[cur_name] = cur
    return comps, entry_name


def _dot_flops(instr: Instr, symtab: Dict[str, str]) -> float:
    res_dims = _first_shape_dims(instr.result_text)
    if res_dims is None:
        return 0.0
    out = 1.0
    for d in res_dims:
        out *= d
    # lhs operand: first %name reference (or inline shape)
    lhs_dims = None
    names = _OPERAND_NAME_RE.findall(instr.operand_text)
    if names and names[0] in symtab:
        lhs_dims = _first_shape_dims(symtab[names[0]])
    if lhs_dims is None:
        lhs_dims = _first_shape_dims(instr.operand_text)
    m = _LHS_CONTRACT_RE.search(instr.tail) or _LHS_CONTRACT_RE.search(instr.line)
    contract = 1.0
    if lhs_dims and m and m.group(1):
        for idx in m.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
    return 2.0 * out * contract


def _split_operand_entries(text: str) -> List[str]:
    """Split an operand list at top-level commas. Commas inside shape
    brackets (``f32[32,128]``), layout braces (``{2,1,0}``) and nested
    tuples stay attached to their operand."""
    entries: List[str] = []
    depth, start = 0, 0
    for i, ch in enumerate(text):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        elif ch == "," and depth == 0:
            entries.append(text[start:i])
            start = i + 1
    entries.append(text[start:])
    return [e for e in (e.strip() for e in entries) if e]


def _entry_bytes(entry: str, symtab: Dict[str, str]) -> float:
    """Bytes of ONE operand: resolved through the symbol table when the
    entry references a known ``%name`` (the scheduled HLO also prints the
    shape inline — counting both would double-charge every operand),
    falling back to the inline-typed shape otherwise."""
    m = _OPERAND_NAME_RE.search(entry)
    if m and m.group(1) in symtab:
        return float(_all_shape_bytes(symtab[m.group(1)]))
    return float(_all_shape_bytes(entry))


def _operand_bytes(instr: Instr, symtab: Dict[str, str]) -> float:
    return sum(_entry_bytes(e, symtab)
               for e in _split_operand_entries(instr.operand_text))


def _instr_bytes(instr: Instr, symtab: Dict[str, str]) -> float:
    op = instr.opcode
    if op in _NO_TRAFFIC_OPS:
        return 0.0
    if op == "dynamic-update-slice":
        names = _OPERAND_NAME_RE.findall(instr.operand_text)
        if len(names) >= 2 and names[1] in symtab:
            return 2.0 * _all_shape_bytes(symtab[names[1]])
        return 0.0
    if op in ("dynamic-slice", "gather", "slice"):
        # only the sliced/gathered elements move, not the whole operand
        return 2.0 * float(_all_shape_bytes(instr.result_text))
    if op == "scatter":
        # read+write of the updated region ≈ 3× the updates operand
        names = _OPERAND_NAME_RE.findall(instr.operand_text)
        if len(names) >= 3 and names[2] in symtab:
            return 3.0 * _all_shape_bytes(symtab[names[2]])
        return 3.0 * float(_all_shape_bytes(instr.result_text))
    return _operand_bytes(instr, symtab) + float(
        _all_shape_bytes(instr.result_text))


def _fusion_param_effective_bytes(comps, symtabs, fusion_comp: str):
    """Per-parameter effective read bytes for a fusion computation.

    A parameter consumed ONLY by dynamic-slice/slice/gather ops is read
    slice-wise (e.g., the backward loop reading one layer of a stacked
    residual) — charging the full stacked operand would overstate HBM
    traffic by the trip count. Returns {param_index: bytes or None(=full)}.
    """
    if fusion_comp not in comps:
        return {}
    instrs = comps[fusion_comp]
    symtab = symtabs[fusion_comp]
    # param name -> index, from "parameter(i)" text
    param_idx = {}
    for ins in instrs:
        if ins.opcode == "parameter":
            m = re.search(r"^\s*(\d+)", ins.operand_text)
            if m:
                param_idx[ins.name] = int(m.group(1))
    sliced_only: Dict[str, Optional[float]] = {}
    for pname in param_idx:
        uses = []
        for ins in instrs:
            if ins.opcode == "parameter":
                continue
            if re.search(r"%" + re.escape(pname) + r"\b", ins.operand_text):
                uses.append(ins)
        if uses and all(u.opcode in ("dynamic-slice", "slice", "gather",
                                     "dynamic-update-slice") for u in uses):
            total = 0.0
            for u in uses:
                if u.opcode == "dynamic-update-slice":
                    ops = _OPERAND_NAME_RE.findall(u.operand_text)
                    if len(ops) >= 2 and ops[1] in symtab:
                        total += 2.0 * _all_shape_bytes(symtab[ops[1]])
                else:
                    total += float(_all_shape_bytes(u.result_text))
            sliced_only[pname] = total
        else:
            sliced_only[pname] = None
    return {param_idx[p]: v for p, v in sliced_only.items()}


def analyze(hlo_text: str) -> Cost:
    comps, entry = parse_computations(hlo_text)
    if entry is None:
        raise ValueError("no ENTRY computation found in HLO text")

    symtabs: Dict[str, Dict[str, str]] = {
        cname: {i.name: i.result_text for i in instrs}
        for cname, instrs in comps.items()
    }
    fusion_param_cache: Dict[str, Dict[int, Optional[float]]] = {}

    memo: Dict[Tuple[str, bool], Cost] = {}

    def comp_cost(name: str, flops_only: bool, depth: int = 0) -> Cost:
        key = (name, flops_only)
        if key in memo:
            return memo[key]
        if name not in comps or depth > 50:
            return Cost()
        symtab = symtabs[name]
        total = Cost()
        for ins in comps[name]:
            op = ins.opcode
            if op == "dot":
                total.flops += _dot_flops(ins, symtab)
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLLECTIVES and not op.endswith("-done"):
                nb = _operand_bytes(ins, symtab)
                if nb == 0:
                    nb = _all_shape_bytes(ins.result_text)
                total.coll[base] = total.coll.get(base, 0.0) + nb
            if not flops_only:
                if op == "fusion":
                    attrs0 = ins.tail + " " + ins.line
                    subs = _CALLS_RE.findall(attrs0)
                    eff = {}
                    if subs:
                        sub = subs[0]
                        if sub not in fusion_param_cache:
                            fusion_param_cache[sub] = \
                                _fusion_param_effective_bytes(comps, symtabs,
                                                              sub)
                        eff = fusion_param_cache[sub]
                    b = float(_all_shape_bytes(ins.result_text))
                    # fusion operands map positionally to the called
                    # computation's parameters; a slice-only parameter is
                    # charged its effective (sliced) bytes, never more
                    # than the full operand
                    entries = _split_operand_entries(ins.operand_text)
                    for i, entry in enumerate(entries):
                        full = _entry_bytes(entry, symtab)
                        e = eff.get(i)
                        b += min(e, full) if e is not None else full
                    total.bytes += b
                else:
                    total.bytes += _instr_bytes(ins, symtab)
            attrs = ins.tail + " " + ins.line
            if op == "while":
                trips = 1.0
                tm = _TRIP_RE.search(ins.line)
                if tm:
                    trips = float(tm.group(1))
                bm = _BODY_RE.search(attrs)
                if bm:
                    total.add(comp_cost(bm.group(1), flops_only, depth + 1),
                              trips)
                cm = _COND_RE.search(attrs)
                if cm:
                    total.add(comp_cost(cm.group(1), flops_only, depth + 1),
                              trips)
            elif op in _FUSION_LIKE:
                for sub in _CALLS_RE.findall(attrs):
                    total.add(comp_cost(sub, True, depth + 1), 1.0)
            elif op == "conditional":
                brm = _BRANCHES_RE.search(attrs)
                if brm:
                    subs = _OPERAND_NAME_RE.findall(brm.group(1))
                    costs = [comp_cost(s, flops_only, depth + 1) for s in subs]
                    if costs:  # worst-case branch
                        total.add(max(costs, key=lambda c: c.flops + c.bytes))
            elif op in ("call", "custom-call", "async-start"):
                for sub in _CALLS_RE.findall(attrs):
                    total.add(comp_cost(sub, flops_only, depth + 1), 1.0)
        memo[key] = total
        return total

    return comp_cost(entry, flops_only=False)


def analyze_to_dict(hlo_text: str) -> Dict[str, float]:
    c = analyze(hlo_text)
    out = {"flops": c.flops, "bytes": c.bytes,
           "collective_total": c.coll_total}
    for k, v in c.coll.items():
        out[f"collective_{k}"] = v
    return out
