"""§Roofline table: reads the dry-run artifacts (launch/dryrun.py JSON) and
prints per (arch × shape × mesh) the three roofline terms, dominant
bottleneck, and the 6ND/HLO useful-compute ratio."""
from __future__ import annotations

import glob
import json
import os

import jax

from benchmarks.common import row
from repro.configs import get_config
from repro.configs.shapes import INPUT_SHAPES
from repro.roofline.analysis import format_table, roofline_from_artifacts


def load_reports(art_dir: str = "artifacts/dryrun"):
    reports, skips, fails = [], [], []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") == "skip":
            skips.append(rec)
            continue
        if rec.get("status") != "ok":
            fails.append(rec)
            continue
        cfg = get_config(rec["arch"])
        hc = rec["hlo_cost"]
        coll = {"total": hc.get("collective_total", 0)}
        cost = {"flops": hc.get("flops", 0), "bytes accessed": hc.get("bytes", 0)}
        shape = INPUT_SHAPES[rec["shape"]]
        mode = "train" if shape.mode == "train" else "inference"
        rep = roofline_from_artifacts(
            rec["arch"], rec["shape"], rec["mesh"], rec["chips"],
            cost=cost, collectives=coll, memory=rec.get("memory"),
            cfg=cfg, total_params=rec["num_params"], tokens=rec["tokens"],
            mode=("train" if shape.mode == "train" else "prefill"))
        reports.append(rep)
    return reports, skips, fails


def main(scale=None, full: bool = False, art_dir: str = "artifacts/dryrun"):
    reports, skips, fails = load_reports(art_dir)
    rows = []
    if not reports:
        rows.append(row("roofline/table", 0,
                        f"no artifacts in {art_dir} — run "
                        "`python -m repro.launch.dryrun --all` first"))
        return rows
    print(format_table(reports))
    for r in reports:
        rows.append(row(
            f"roofline/{r.arch}/{r.shape}/{r.mesh}", 0,
            f"dominant={r.dominant};compute_s={r.compute_s:.4f};"
            f"memory_s={r.memory_s:.4f};coll_s={r.collective_s:.4f};"
            f"useful={r.useful_flops_ratio:.3f};fits={r.fits_hbm}"))
    for s in skips:
        rows.append(row(f"roofline/{s['arch']}/{s['shape']}/{s['mesh']}", 0,
                        f"SKIP:{s['skip_reason'][:60]}"))
    for s in fails:
        rows.append(row(f"roofline/{s['arch']}/{s['shape']}/{s['mesh']}", 0,
                        f"FAIL:{s.get('error','')[:60]}"))
    return rows
