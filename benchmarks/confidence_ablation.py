"""Paper §4.2.2 'Choice of the confidence measure': most-confident target
selection vs random target selection. Paper claim: random selection degrades
both β_priv and the last aux head's β_sh, more so for skewed data."""
from __future__ import annotations

from benchmarks.common import best_aux_sh, make_data, row, run_mhd


def main(scale, full: bool = False) -> list:
    rows = []
    skews = (0.0, 100.0) if full else (100.0,)
    for s in skews:
        data = make_data(scale, skew=s)
        # "max"/"random" reproduce the paper's §4.2.2 ablation; "entropy"
        # and "margin" are the beyond-paper Λ alternatives (App. A.2
        # future work, implemented in core/mhd.py)
        for conf in ("max", "random", "entropy", "margin"):
            ev = run_mhd(scale, aux_heads=3, skew=s, confidence=conf,
                         data=data)
            derived = (f"s={s:g};confidence={conf};"
                       f"main_priv={ev['mean/main/beta_priv']:.3f};"
                       f"best_sh={best_aux_sh(ev):.3f}")
            rows.append(row("confidence/ablation", ev["_step_us"], derived))
        # the single-head 'ignore poor targets' rule (§4.2.2)
        ev = run_mhd(scale, aux_heads=1, skew=s, skip_confident=True,
                     data=data)
        rows.append(row("confidence/skip_if_student_confident",
                        ev["_step_us"],
                        f"s={s:g};best_sh={best_aux_sh(ev):.3f}"))
    return rows
