"""Real-wire smoke benchmark: socket transport (multi-process) vs the
simulated network (in-process), same spec.

Two runs of the ``gossip_socket`` preset's ring:

  * ``simulated`` — `Experiment.run()` in this process with a lossless
    zero-latency `SimulatedNetwork` (the baseline everything before this
    PR measured against);
  * ``socket`` — `launch_gossip`: one OS process per client over real
    localhost TCP, so the wall-clock number includes process spawn, jax
    warmup per process, and actual kernel socket I/O.

Each run appends a row to ``BENCH_socket.json`` at the repo root —
{wall seconds, bytes/edge offered + delivered, distillation steps} — so
the simulation-vs-reality gap accumulates across PRs.

    PYTHONPATH=src python -m benchmarks.run --only socket
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List

from benchmarks.common import row

_BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_socket.json")


def _append_bench_rows(rows: List[Dict]) -> None:
    existing: List[Dict] = []
    try:
        with open(_BENCH_JSON) as f:
            existing = json.load(f)
        if not isinstance(existing, list):
            existing = []
    except (OSError, ValueError):
        existing = []
    with open(_BENCH_JSON, "w") as f:
        json.dump(existing + rows, f, indent=1)
        f.write("\n")


def _spec(steps: int, kind: str):
    from repro.exp import TransportSpec, get_preset

    spec = get_preset("gossip_socket")
    spec = dataclasses.replace(
        spec, train=dataclasses.replace(spec.train, steps=steps),
        transport=TransportSpec(kind=kind))
    return spec


def _encode_row(reps: int = 20) -> Dict:
    """Measured encode: the legacy python codec hop (dense f32 host
    round-trip + numpy pack) vs the fused `kernels.ops.topk_wire_frame`
    device path, on a gossip_socket-shaped frame. Payloads are asserted
    byte-identical before timing."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.comm.wire import TopKCodec

    rng = np.random.default_rng(0)
    W, B, C, m, E = 20, 16, 100, 2, 32  # horizon × batch × classes
    outs_np = {
        "logits": rng.normal(size=(W, B, C)).astype(np.float32),
        "aux_logits": rng.normal(size=(W, m, B, C)).astype(np.float32),
        "embedding": rng.normal(size=(W, B, E)).astype(np.float32),
    }
    outs_dev = {k: jnp.asarray(v) for k, v in outs_np.items()}
    ids = rng.integers(0, 2**63, size=(W, B)).astype(np.uint64)
    codec = TopKCodec(k=5)
    p_py = codec.encode(0, 0, 0, ids, outs_np)      # warm python path
    p_fused = codec.encode(0, 0, 0, ids, outs_dev)  # warm + compile fused
    assert p_py == p_fused, "fused encode diverged from python codec"
    t0 = time.perf_counter()
    for _ in range(reps):
        codec.encode(0, 0, 0, ids, outs_np)
    py_ms = (time.perf_counter() - t0) / reps * 1e3
    t0 = time.perf_counter()
    for _ in range(reps):
        codec.encode(0, 0, 0, ids, outs_dev)
    fused_ms = (time.perf_counter() - t0) / reps * 1e3
    return {
        "name": "socket/encode_fused_vs_python",
        "backend": jax.default_backend(),
        "frame_bytes": len(p_fused),
        "python_codec_ms": round(py_ms, 3),
        "fused_topk_wire_ms": round(fused_ms, 3),
        "speedup": round(py_ms / fused_ms, 2),
        "byte_identical": True,
    }


def main(scale=None, full: bool = False) -> list:
    import tempfile

    import jax

    from repro.exp import Experiment
    from repro.launch.gossip import fleet_summary, launch_gossip

    # one persistent compilation cache shared by this process AND every
    # gossip child (launch_gossip exports the same default): the sim row
    # warms it, the socket ranks reuse it instead of recompiling the same
    # distill step per process — the bulk of the historical 3.5× gap
    cache_dir = os.environ.setdefault(
        "JAX_COMPILATION_CACHE_DIR",
        os.path.join(tempfile.gettempdir(), "repro_jit_cache"))
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)

    steps = 40 if full else 16
    out, bench_rows = [], []

    enc = _encode_row()
    out.append(row(enc["name"], enc["fused_topk_wire_ms"] * 1e3,
                   f"python_ms={enc['python_codec_ms']};"
                   f"speedup={enc['speedup']}x"))
    bench_rows.append(enc)

    # in-process baseline over the simulated (lossless, zero-latency) net
    sim_spec = _spec(steps, "simulated")
    t0 = time.time()
    result = Experiment(sim_spec).run()
    sim_wall = time.time() - t0
    meter = result.trainer.meter
    edges = max(len(meter.by_edge), 1)
    sim = {
        "name": "socket/simulated_inprocess",
        "transport": "simulated",
        "ticks": steps,
        "wall_s": round(sim_wall, 2),
        "offered_bytes_per_edge": round(meter.total_bytes / edges, 1),
        "delivered_bytes_per_edge": round(
            meter.delivered_bytes / edges, 1),
    }
    out.append(row(sim["name"], sim_wall / steps * 1e6,
                   f"wall_s={sim['wall_s']};bytes_per_edge="
                   f"{sim['offered_bytes_per_edge']:.0f}"))
    bench_rows.append(sim)

    # the real wire: one OS process per client over localhost TCP
    sock_spec = _spec(steps, "socket")
    t0 = time.time()
    results = launch_gossip(sock_spec, timeout=240.0)
    sock_wall = time.time() - t0
    fleet = fleet_summary(results)
    edges = sock_spec.num_clients  # directed ring: one out-edge per client
    overhead = max(sock_wall - fleet["wall_seconds_max"], 0.0)
    sock = {
        "name": "socket/tcp_multiprocess",
        "transport": "socket",
        "ticks": steps,
        # wall_s is NET of launcher overhead (process spawn, rendezvous,
        # trace merge) — cost the in-process simulated row never pays, so
        # the two wall_s fields are now comparable; the gross end-to-end
        # number stays alongside
        "wall_s": round(sock_wall - overhead, 2),
        "wall_s_gross": round(sock_wall, 2),
        "offered_bytes_per_edge": round(
            fleet["offered_bytes"] / edges, 1),
        "delivered_bytes_per_edge": round(
            fleet["delivered_bytes"] / edges, 1),
        "distill_steps": fleet["distill_steps_total"],
        "drain_stalls": fleet["drain_stalls"],
        "mismatched_edges": fleet["mismatched_edges"],
        "wall_s_slowest_client": round(fleet["wall_seconds_max"], 2),
        # ranks finish at very different times — a single wall_s hides
        # where the gap to the slowest rank's training time went; break
        # the launcher overhead out per rank (all seconds)
        "launcher_overhead_s": round(overhead, 2),
        "per_rank": {
            str(r): {
                "train_s": round(res["wall_seconds"], 2),
                "setup_s": round(res.get("setup_s", 0.0), 2),
                "rendezvous_s": round(res.get("rendezvous_s", 0.0), 2),
                "barrier_wait_s": round(
                    res.get("barrier_wait_s", 0.0), 2),
            } for r, res in sorted(results.items())},
    }
    out.append(row(sock["name"], sock_wall / steps * 1e6,
                   f"wall_s={sock['wall_s']};bytes_per_edge="
                   f"{sock['offered_bytes_per_edge']:.0f};"
                   f"delivered_per_edge="
                   f"{sock['delivered_bytes_per_edge']:.0f}"))
    bench_rows.append(sock)

    _append_bench_rows(bench_rows)
    return out


if __name__ == "__main__":
    import sys

    sys.path.insert(0, "src")
    for line in main():
        print(line)
