"""Paper Fig. 4 / Tables 7-8: effect of the number of auxiliary heads.

Paper claim (s=100): deeper head chains raise the final head's shared
accuracy; the main head keeps the best private accuracy."""
from __future__ import annotations

from benchmarks.common import make_data, row, run_mhd


def main(scale, full: bool = False) -> list:
    rows = []
    head_counts = [1, 2, 3, 4] if full else [1, 2, 3]
    data = make_data(scale, skew=100.0)
    for m in head_counts:
        ev = run_mhd(scale, aux_heads=m, skew=100.0, data=data)
        last_sh = ev[f"mean/aux{m}/beta_sh"]
        derived = (f"heads={m};main_priv={ev['mean/main/beta_priv']:.3f};"
                   f"main_sh={ev['mean/main/beta_sh']:.3f};"
                   f"last_aux_sh={last_sh:.3f}")
        rows.append(row("fig4/heads", ev["_step_us"], derived))
    return rows
