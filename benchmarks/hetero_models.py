"""Paper §4.5: heterogeneous ensembles — (a) small models benefit from a
larger teacher in the ensemble; (b) a large model distilling from small
specialists beats what small-only ensembles reach, and (c) the same large
model in isolation is far worse."""
from __future__ import annotations

import numpy as np

from benchmarks.common import client_beta_sh, make_data, row, run_mhd
from repro.core.supervised import eval_per_label_accuracy, train_supervised
from repro.exp import ClientSpec
from repro.models.resnet import resnet_tiny34
from repro.models.zoo import build_bundle
from repro.optim.optimizers import OptimizerConfig, make_optimizer


def main(scale, full: bool = False) -> list:
    rows = []
    data = make_data(scale, skew=100.0)
    arrays, test_arrays, part = data
    K = scale.clients

    # all-small ensemble
    small = tuple(ClientSpec("resnet_tiny", aux_heads=3) for _ in range(K))
    ev_small = run_mhd(scale, aux_heads=3, skew=100.0, clients=small,
                       data=data)
    small_sh = client_beta_sh(ev_small, K, "aux3")
    rows.append(row("hetero/all_small", ev_small["_step_us"],
                    f"mean_sh={np.mean(small_sh):.3f}"))

    # one big + (K-1) small
    mixed = (ClientSpec("resnet_tiny34", aux_heads=3),) + tuple(
        ClientSpec("resnet_tiny", aux_heads=3) for _ in range(K - 1))
    ev_mixed = run_mhd(scale, aux_heads=3, skew=100.0, clients=mixed,
                       data=data)
    mixed_sh = client_beta_sh(ev_mixed, K, "aux3")
    rows.append(row("hetero/big_plus_small", ev_mixed["_step_us"],
                    f"big_sh={mixed_sh[0]:.3f};"
                    f"smalls_sh={np.mean(mixed_sh[1:]):.3f};"
                    f"smalls_with_small_teachers={np.mean(small_sh[1:]):.3f}"))

    # the big model in isolation on its own shard (paper: 39.4% vs 68.6%)
    opt = make_optimizer(OptimizerConfig(init_lr=scale.lr,
                                         total_steps=scale.steps))
    big = build_bundle(resnet_tiny34(scale.labels))
    params = train_supervised(big, opt, arrays, part.client_indices[0],
                              steps=scale.steps, batch_size=scale.batch_size)
    pl, pres = eval_per_label_accuracy(big, params, test_arrays, scale.labels)
    rows.append(row("hetero/big_isolated", 0,
                    f"big_sh={pl[pres].mean():.3f}"))
    return rows
