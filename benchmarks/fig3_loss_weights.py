"""Paper Fig. 3 / Tables 5-6: sweep of ν_emb × ν_aux at s=0 and s=100.

Paper claims reproduced qualitatively: (a) ν_aux > 0 beats ν_aux = 0,
(b) combining both losses is best, (c) excessive weights degrade."""
from __future__ import annotations

from benchmarks.common import best_aux_sh, make_data, row, run_mhd


def main(scale, full: bool = False) -> list:
    rows = []
    # at CPU scale the deterioration threshold sits near nu_aux≈3 (the
    # paper's 1000-way optimum) — include nu_aux=1 so the peak is visible
    nu_embs = [0.0, 1.0, 3.0] if full else [0.0, 1.0]
    nu_auxs = [0.0, 1.0, 3.0, 10.0] if full else [0.0, 1.0, 3.0]
    for s in (0.0, 100.0):
        data = make_data(scale, skew=s)
        for ne in nu_embs:
            for na in nu_auxs:
                ev = run_mhd(scale, nu_emb=ne, nu_aux=na, skew=s, data=data)
                derived = (f"s={s:g};nu_emb={ne:g};nu_aux={na:g};"
                           f"main_priv={ev['mean/main/beta_priv']:.3f};"
                           f"main_sh={ev['mean/main/beta_sh']:.3f};"
                           f"best_sh={best_aux_sh(ev):.3f}")
                rows.append(row("fig3/sweep", ev["_step_us"], derived))
    return rows
