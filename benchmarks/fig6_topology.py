"""Paper Figs. 5-6 (§4.4): communication-topology effects & transitive
distillation. Islands vs cycle vs complete with 4 clients; per-hop accuracy
of each head on the teacher-at-distance-d's primary labels.

Paper claims: cycle ≫ islands on shared accuracy (transitive distillation
through intermediaries), and later aux heads reach further hops."""
from __future__ import annotations

import numpy as np

from benchmarks.common import make_data, row, run_mhd_result
from repro.core.graph import (
    complete_graph,
    cycle_graph,
    graph_distance_matrix,
    islands_graph,
)
from repro.core.supervised import eval_per_label_accuracy


def _hop_accuracy(trainer, part, test_arrays, graph, num_labels, aux_heads):
    """acc[head][hop] = student accuracy on primary labels of clients at
    that graph distance (averaged over student/teacher pairs)."""
    K = len(trainer.clients)
    dist = graph_distance_matrix(graph)
    heads = ["main"] + [f"aux{h+1}" for h in range(aux_heads)]
    acc = {h: {} for h in heads}
    for i, c in enumerate(trainer.clients):
        for hi, head in enumerate(heads):
            per_label, present = eval_per_label_accuracy(
                c.bundle, c.params, test_arrays, num_labels,
                head=("main" if head == "main" else f"aux{hi}"))
            for j in range(K):
                if i == j or not np.isfinite(dist[i, j]):
                    continue
                labs = part.primary_labels[j]
                hop = int(dist[i, j])
                acc[head].setdefault(hop, []).append(per_label[labs].mean())
    return {h: {hop: float(np.mean(v)) for hop, v in hops.items()}
            for h, hops in acc.items()}


def main(scale, full: bool = False) -> list:
    rows = []
    aux_heads = 3
    for topo_name in ("islands", "cycle", "complete"):
        data = make_data(scale, skew=100.0)
        res = run_mhd_result(scale, aux_heads=aux_heads, skew=100.0,
                             topology=topo_name, data=data)
        ev, trainer = res.metrics, res.trainer  # trainer rides out-of-band
        graph = {"complete": complete_graph(scale.clients),
                 "cycle": cycle_graph(scale.clients),
                 "islands": islands_graph(scale.clients, 2)}[topo_name]
        arrays, test_arrays, part = data
        hops = _hop_accuracy(trainer, part, test_arrays, graph,
                             scale.labels, aux_heads)
        last = f"aux{aux_heads}"
        hop_str = ";".join(
            f"hop{h}={hops[last].get(h, float('nan')):.3f}"
            for h in sorted(hops[last]))
        derived = (f"topology={topo_name};"
                   f"sh_last={ev[f'mean/{last}/beta_sh']:.3f};"
                   f"sh_main={ev['mean/main/beta_sh']:.3f};{hop_str}")
        rows.append(row("fig6/topology", res.us_per_step, derived))
    return rows
