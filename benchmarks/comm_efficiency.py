"""Paper §'Communication efficiency' (§3.2): bytes exchanged per step.

Prediction distillation transmits a few top-k logits per public sample
(samples identified by hash); FedAvg transmits the full model both ways.
The paper estimates one FedAvg round of ResNet-34 ≈ 50k distillation steps;
we compute the same accounting for the paper's models AND for the assigned
LLM architectures (where the full-vocab exchange would be large — motivating
the top-k wire format measured in §Perf).

The accounting is the shared `repro.comm.wire` byte model — the same code
the runtime's `PredictionBus` meters — and a real `TopKCodec` encode is
measured against it (formula vs. actual serialized payload).

Also microbenchmarks the fused dist_ce kernel path (interpret) vs the jnp
reference on a 262k-vocab batch — the MHD hot spot.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.comm.wire import TopKCodec, topk_frame_nbytes
from repro.configs import get_config
from repro.models.zoo import build_bundle
from repro.common.pytree import tree_size


def _mhd_bytes_per_step(batch: int, topk: int, delta: int,
                        num_heads: int = 1, emb_dim: int = 0) -> int:
    """Bytes of Δ teachers' top-k predictions for one public batch.

    Defaults reproduce the paper's §3.2 accounting: (f16 value + i32
    index) per retained logit + 8-byte sample hash, main head only, no
    embedding. Pass num_heads/emb_dim for the full MHD wire format."""
    return delta * topk_frame_nbytes(batch, topk, num_heads=num_heads,
                                     emb_dim=emb_dim)


def main(scale=None, full: bool = False) -> list:
    rows = []
    # --- paper's accounting: ResNet-34, batch 512, top-5 predictions
    resnet34_params = 21.8e6
    fedavg_round = 2 * resnet34_params * 4  # up+down, fp32
    mhd_step = _mhd_bytes_per_step(batch=512, topk=5, delta=1)
    rows.append(row("comm/resnet34", 0,
                    f"fedavg_round_bytes={fedavg_round:.3e};"
                    f"mhd_step_bytes={mhd_step};"
                    f"steps_per_round={fedavg_round/mhd_step:.0f}"))

    # --- assigned LLM archs: full-vocab vs top-k exchange per public batch
    for arch in ("gemma3-12b", "qwen2.5-32b", "deepseek-v3-671b"):
        cfg = get_config(arch)
        n_params = tree_size(jax.eval_shape(
            build_bundle(cfg).init, jax.random.PRNGKey(0)))
        tokens = 512 * 128  # public batch of 512 seqs x 128 positions
        full_ex = tokens * cfg.vocab_size * 2  # bf16 full logits
        topk_ex = _mhd_bytes_per_step(batch=tokens, topk=32, delta=1)
        fedavg = 2 * n_params * 2  # bf16 both ways
        rows.append(row(f"comm/{arch}", 0,
                        f"fedavg_round={fedavg:.3e};"
                        f"full_logits={full_ex:.3e};topk32={topk_ex:.3e};"
                        f"full_over_topk={full_ex/topk_ex:.0f}x"))

    # --- measured wire format: an actual TopKCodec encode vs the formula
    B, C, k, m = 256, 4096, 32, 4
    key = jax.random.PRNGKey(0)
    outs = {
        "embedding": np.asarray(jax.random.normal(key, (1, B, 64))),
        "logits": np.asarray(jax.random.normal(key, (1, B, C))),
        "aux_logits": np.asarray(jax.random.normal(key, (1, m, B, C))),
    }
    codec = TopKCodec(k, val_dtype="float16", emb_encoding="int8")
    ids = np.arange(B, dtype=np.uint64)[None]
    t0 = time.time()
    payload = codec.encode(0, 0, 0, ids, outs)
    enc_us = (time.time() - t0) * 1e6
    formula = topk_frame_nbytes(B, k, num_heads=m + 1, emb_dim=64,
                                val_bytes=2, idx_bytes=2, lse_bytes=4)
    rows.append(row("comm/topk_codec_measured", enc_us,
                    f"payload={len(payload)};formula={formula};"
                    f"overhead={len(payload)/formula:.3f}x"))

    # --- entropy-adaptive wire (repro.lm): adaptive vs fixed-k payloads
    # on an LM-shaped frame (mixed peaked/uncertain next-token teachers)
    from repro.lm import AdaptiveTopKCodec, CompressedCodec

    W, N, V, m = 4, 64, 64, 2  # windows x tokens x vocab, 3 heads
    rng = np.random.default_rng(0)
    lm_outs = {
        "logits": rng.normal(size=(W, N, V)).astype(np.float32),
        "aux_logits": rng.normal(size=(W, m, N, V)).astype(np.float32),
    }
    lm_outs["logits"][:, ::2, 0] = 20.0  # half the tokens near-certain
    lm_ids = np.arange(W * N, dtype=np.uint64).reshape(W, N)
    fixed_codec = TopKCodec(8, val_dtype="float16", emb_encoding="none")
    p_fixed = fixed_codec.encode(0, 0, 0, lm_ids, lm_outs)
    for budget in (24, 16, 8):
        adap = AdaptiveTopKCodec(8, budget_bytes_per_token=budget,
                                 emb_encoding="none")
        adap.encode(0, 0, 0, lm_ids, lm_outs)  # warm the jitted frame
        t0 = time.time()
        p_adap = adap.encode(0, 0, 0, lm_ids, lm_outs)
        enc_us = (time.time() - t0) * 1e6
        rows.append(row(f"comm/adaptive_vs_fixed_k8_b{budget}", enc_us,
                        f"adaptive={len(p_adap)};fixed_k8={len(p_fixed)};"
                        f"savings={1 - len(p_adap)/len(p_fixed):.2f}"))

    # --- compression wrapper: XOR-delta + bit-packed index streams
    for name, inner, mk in (
            ("adaptive_b16",
             AdaptiveTopKCodec(8, budget_bytes_per_token=16,
                               emb_encoding="none"),
             lambda: CompressedCodec(AdaptiveTopKCodec(
                 8, budget_bytes_per_token=16, emb_encoding="none"))),
            ("fixed_k8", fixed_codec,
             lambda: CompressedCodec(TopKCodec(
                 8, val_dtype="float16", emb_encoding="none")))):
        p_raw = inner.encode(0, 0, 0, lm_ids, lm_outs)
        comp = mk()
        comp.encode(0, 0, 0, lm_ids, lm_outs)  # warm
        t0 = time.time()
        p_comp = comp.encode(0, 0, 0, lm_ids, lm_outs)
        enc_us = (time.time() - t0) * 1e6
        rows.append(row(f"comm/compressed_vs_raw_{name}", enc_us,
                        f"compressed={len(p_comp)};raw={len(p_raw)};"
                        f"savings={1 - len(p_comp)/len(p_raw):.2f}"))

    # --- dist_ce hot-spot microbench (jnp reference path, CPU wall time)
    from repro.kernels.ref import dist_ce_ref

    B, V = 256, 262_144
    s = jax.random.normal(jax.random.PRNGKey(0), (B, V), jnp.float32)
    t = jax.random.normal(jax.random.PRNGKey(1), (B, V), jnp.float32)
    f = jax.jit(dist_ce_ref)
    f(s, t)[0].block_until_ready()
    t0 = time.time()
    for _ in range(3):
        f(s, t)[0].block_until_ready()
    us = (time.time() - t0) / 3 * 1e6
    rows.append(row("comm/dist_ce_ref_256x262k", us,
                    f"bytes_touched={3*B*V*4:.2e}"))
    return rows
