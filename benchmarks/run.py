"""Benchmark orchestrator — one entry per paper table/figure + the roofline
table. Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run             # quick (default)
  PYTHONPATH=src python -m benchmarks.run --full      # paper-scale-ish sweep
  PYTHONPATH=src python -m benchmarks.run --only table1,fig4
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--full", action="store_true")
    p.add_argument("--only", default="",
                   help="comma-separated benchmark keys to run")
    p.add_argument("--art-dir", default="artifacts/dryrun")
    args = p.parse_args(argv)

    from benchmarks import (
        async_staleness,
        comm_efficiency,
        confidence_ablation,
        fig3_loss_weights,
        fig4_num_heads,
        fig6_topology,
        fleet_churn,
        hetero_models,
        lm_hetero_fleet,
        roofline,
        serve,
        socket_gossip,
        table1_baselines,
        table2_fedmd,
        table3_variants,
        table4_public_size,
    )
    from benchmarks.common import FULL, QUICK

    scale = FULL if args.full else QUICK
    benches = [
        ("comm", lambda: comm_efficiency.main(scale, args.full)),
        ("async", lambda: async_staleness.main(scale, args.full)),
        ("socket", lambda: socket_gossip.main(scale, args.full)),
        ("fleet", lambda: fleet_churn.main(scale, args.full)),
        ("serve", lambda: serve.main(scale, args.full)),
        ("lm", lambda: lm_hetero_fleet.main(scale, args.full)),
        ("roofline", lambda: roofline.main(scale, args.full, args.art_dir)),
        ("table1", lambda: table1_baselines.main(scale)),
        ("fig3", lambda: fig3_loss_weights.main(scale, args.full)),
        ("fig4", lambda: fig4_num_heads.main(scale, args.full)),
        ("table3", lambda: table3_variants.main(scale, args.full)),
        ("table4", lambda: table4_public_size.main(scale, args.full)),
        ("fig6", lambda: fig6_topology.main(scale, args.full)),
        ("table2", lambda: table2_fedmd.main(scale, args.full)),
        ("confidence", lambda: confidence_ablation.main(scale, args.full)),
        ("hetero", lambda: hetero_models.main(scale, args.full)),
    ]
    only = {x.strip() for x in args.only.split(",") if x.strip()}

    print("name,us_per_call,derived")
    failures = 0
    for key, fn in benches:
        if only and key not in only:
            continue
        t0 = time.time()
        try:
            for r in fn():
                print(r, flush=True)
            print(f"# {key} done in {time.time()-t0:.0f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# {key} FAILED:", flush=True)
            traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
