"""Paper Table 4 (App. B.2): dependence on public dataset size — larger
public pools improve distillation."""
from __future__ import annotations

from benchmarks.common import best_aux_sh, row, run_mhd


def main(scale, full: bool = False) -> list:
    rows = []
    fracs = [0.05, 0.1, 0.2, 0.3] if full else [0.05, 0.3]
    for g in fracs:
        ev = run_mhd(scale, gamma_pub=g, skew=100.0)
        derived = (f"gamma_pub={g:g};"
                   f"main_priv={ev['mean/main/beta_priv']:.3f};"
                   f"best_sh={best_aux_sh(ev):.3f}")
        rows.append(row("table4/public_size", ev["_step_us"], derived))
    return rows
