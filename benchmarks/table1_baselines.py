"""Paper Table 1: shared accuracy β_sh for Separate / MHD / MHD+ / FedAvg /
Supervised. Paper claim: Separate ≪ MHD < MHD+ ≲ FedAvg ≈ Supervised."""
from __future__ import annotations

import dataclasses

from benchmarks.common import (
    best_aux_sh,
    make_data,
    row,
    run_fedavg_baseline,
    run_mhd,
    run_separate,
    run_supervised_baseline,
)


def main(scale) -> list:
    rows = []
    data = make_data(scale)

    sep = run_separate(scale, data=data)
    rows.append(row("table1/separate", sep["_step_us"],
                    f"beta_sh={sep['mean/main/beta_sh']:.3f}"))

    mhd = run_mhd(scale, data=data)
    rows.append(row("table1/mhd", mhd["_step_us"],
                    f"beta_sh={best_aux_sh(mhd):.3f}"))

    # MHD+ — longer training with a larger public pool (paper: entire
    # ImageNet as public set + 3x steps)
    plus_scale = dataclasses.replace(scale, gamma_pub=0.3,
                                     steps=int(scale.steps * 2))
    mhdp = run_mhd(plus_scale)
    rows.append(row("table1/mhd_plus", mhdp["_step_us"],
                    f"beta_sh={best_aux_sh(mhdp):.3f}"))

    fa = run_fedavg_baseline(scale, average_every=20, data=data)
    rows.append(row("table1/fedavg_u20", fa["_step_us"],
                    f"beta_sh={fa['mean/main/beta_sh']:.3f}"))

    fa2 = run_fedavg_baseline(scale, average_every=max(scale.steps // 2, 1),
                              data=data)
    rows.append(row("table1/fedavg_u_half", fa2["_step_us"],
                    f"beta_sh={fa2['mean/main/beta_sh']:.3f}"))

    sup = run_supervised_baseline(scale, data=data)
    rows.append(row("table1/supervised", sup["_step_us"],
                    f"beta_sh={sup['mean/main/beta_sh']:.3f}"))
    return rows
