"""Paper Table 2: MHD vs FedMD (centralized distillation) with
heterogeneous client architectures. Paper claims: MHD closes more of the
gap to its pooled-data baseline AND has a smaller accuracy spread across
clients than FedMD."""
from __future__ import annotations

import numpy as np

from benchmarks.common import make_data, row, run_mhd
from repro.core.fedmd import train_fedmd
from repro.core.supervised import eval_per_label_accuracy, train_supervised
from repro.models.resnet import resnet_tiny, resnet_tiny34
from repro.models.zoo import build_bundle
from repro.optim.optimizers import OptimizerConfig, make_optimizer


def main(scale, full: bool = False) -> list:
    rows = []
    data = make_data(scale, skew=100.0)
    arrays, test_arrays, part = data
    # heterogeneous ensemble: alternate two architectures (paper: 10 archs)
    bundles = [build_bundle(
        (resnet_tiny34 if i % 2 else resnet_tiny)(scale.labels,
                                                  num_aux_heads=3))
        for i in range(scale.clients)]

    # pooled-data upper baseline ("Base" in Table 2)
    opt = make_optimizer(OptimizerConfig(init_lr=scale.lr,
                                         total_steps=scale.steps))
    pooled = np.concatenate(part.client_indices)
    base_bundle = build_bundle(resnet_tiny(scale.labels))
    base_params = train_supervised(base_bundle, opt, arrays, pooled,
                                   steps=scale.steps,
                                   batch_size=scale.batch_size)
    per_label, present = eval_per_label_accuracy(base_bundle, base_params,
                                                 test_arrays, scale.labels)
    rows.append(row("table2/base_pooled", 0,
                    f"acc={per_label[present].mean():.3f}"))

    # MHD with the heterogeneous ensemble
    ev = run_mhd(scale, aux_heads=3, skew=100.0, bundles=bundles, data=data)
    trainer = ev.pop("_trainer")
    accs = []
    for c in trainer.clients:
        pl, pres = eval_per_label_accuracy(c.bundle, c.params, test_arrays,
                                           scale.labels, head="aux3")
        accs.append(pl[pres].mean())
    rows.append(row("table2/mhd", ev["_step_us"],
                    f"acc={np.mean(accs):.3f};spread={np.std(accs):.3f}"))

    # FedMD
    fedmd_bundles = [build_bundle(
        (resnet_tiny34 if i % 2 else resnet_tiny)(scale.labels))
        for i in range(scale.clients)]
    import time
    t0 = time.time()
    params = train_fedmd(fedmd_bundles, opt, arrays, part.client_indices,
                         part.public_indices, steps=scale.steps,
                         batch_size=scale.batch_size,
                         public_batch_size=scale.batch_size)
    us = (time.time() - t0) / (scale.steps * scale.clients) * 1e6
    accs = []
    for b, p in zip(fedmd_bundles, params):
        pl, pres = eval_per_label_accuracy(b, p, test_arrays, scale.labels)
        accs.append(pl[pres].mean())
    rows.append(row("table2/fedmd", us,
                    f"acc={np.mean(accs):.3f};spread={np.std(accs):.3f}"))
    return rows
