"""Paper Table 2: MHD vs FedMD (centralized distillation) with
heterogeneous client architectures. Paper claims: MHD closes more of the
gap to its pooled-data baseline AND has a smaller accuracy spread across
clients than FedMD."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (client_beta_sh, make_data, row, run_fedmd,
                               run_mhd)
from repro.core.supervised import eval_per_label_accuracy, train_supervised
from repro.exp import ClientSpec
from repro.models.resnet import resnet_tiny
from repro.models.zoo import build_bundle
from repro.optim.optimizers import OptimizerConfig, make_optimizer


def main(scale, full: bool = False) -> list:
    rows = []
    data = make_data(scale, skew=100.0)
    arrays, test_arrays, part = data
    # heterogeneous fleet: alternate two architectures (paper: 10 archs)
    def fleet(aux_heads):
        return tuple(
            ClientSpec(arch=("resnet_tiny34" if i % 2 else "resnet_tiny"),
                       aux_heads=aux_heads)
            for i in range(scale.clients))

    # pooled-data upper baseline ("Base" in Table 2)
    opt = make_optimizer(OptimizerConfig(init_lr=scale.lr,
                                         total_steps=scale.steps))
    pooled = np.concatenate(part.client_indices)
    base_bundle = build_bundle(resnet_tiny(scale.labels))
    base_params = train_supervised(base_bundle, opt, arrays, pooled,
                                   steps=scale.steps,
                                   batch_size=scale.batch_size)
    per_label, present = eval_per_label_accuracy(base_bundle, base_params,
                                                 test_arrays, scale.labels)
    rows.append(row("table2/base_pooled", 0,
                    f"acc={per_label[present].mean():.3f}"))

    # MHD with the heterogeneous ensemble
    ev = run_mhd(scale, aux_heads=3, skew=100.0, clients=fleet(3), data=data)
    accs = client_beta_sh(ev, scale.clients, "aux3")
    rows.append(row("table2/mhd", ev["_step_us"],
                    f"acc={np.mean(accs):.3f};spread={np.std(accs):.3f}"))

    # FedMD through the same runner and the same shared evaluator
    ev = run_fedmd(scale, clients=fleet(0), skew=100.0, data=data)
    accs = client_beta_sh(ev, scale.clients, "main")
    rows.append(row("table2/fedmd", ev["_step_us"],
                    f"acc={np.mean(accs):.3f};spread={np.std(accs):.3f}"))
    return rows
