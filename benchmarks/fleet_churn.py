"""Elastic-fleet benchmark: churn rate × topology, plus the startup-cost
row for the two init schemes (repro.fleet, ISSUE 5).

Two sweeps, appended to ``BENCH_fleet.json`` at the repo root:

  * **churn × topology** — the `churn_ring`-style MHD run over a
    complete graph and a ring, at three churn rates (static fleet; one
    kill+restart; two staggered kill+restarts). Reports final mean
    accuracy, tombstoned bytes (the metered cost of mail addressed to
    dead clients), and wall time — the churn axis next to the paper's
    topology axis (Fig. 6).
  * **startup: legacy vs per_client** — wall time for one gossip child
    (``local_clients=[0]``) to construct its trainer at fleet sizes K.
    The legacy scheme replays the whole fleet's init stream in every
    process (O(K) work per child, O(K²) fleet-wide); ``per_client``
    folds the seed per client id and materializes one model (O(1) per
    child, O(K) fleet-wide).

    PYTHONPATH=src python -m benchmarks.run --only fleet
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List

from benchmarks.common import row

_BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_fleet.json")


def _append_bench_rows(rows: List[Dict]) -> None:
    existing: List[Dict] = []
    try:
        with open(_BENCH_JSON) as f:
            existing = json.load(f)
        if not isinstance(existing, list):
            existing = []
    except (OSError, ValueError):
        existing = []
    with open(_BENCH_JSON, "w") as f:
        json.dump(existing + rows, f, indent=1)
        f.write("\n")


def _churn_events(rate: str, steps: int):
    from repro.exp import ChurnEventSpec

    third = steps // 3
    if rate == "none":
        return ()
    if rate == "one":
        return (ChurnEventSpec(kind="kill", step=third, client=1),
                ChurnEventSpec(kind="restart", step=2 * third, client=1,
                               from_snapshot=False))
    if rate == "two":
        return (ChurnEventSpec(kind="kill", step=third, client=1),
                ChurnEventSpec(kind="kill", step=third + 4, client=2),
                ChurnEventSpec(kind="restart", step=2 * third, client=1,
                               from_snapshot=False),
                ChurnEventSpec(kind="restart", step=2 * third + 4,
                               client=2, from_snapshot=False))
    raise ValueError(rate)


def _churn_spec(topology: str, rate: str, steps: int):
    from repro.exp import ChurnSpec, TopologySpec, get_preset

    spec = get_preset("churn_ring")
    return dataclasses.replace(
        spec,
        name=f"fleet_{topology}_{rate}",
        topology=TopologySpec(topology),
        train=dataclasses.replace(spec.train, steps=steps),
        churn=ChurnSpec(events=_churn_events(rate, steps)))


def _startup_row(K: int, scheme: str) -> Dict:
    """Construction wall time of ONE gossip child (rank 0) at fleet
    size K under the given init scheme."""
    from repro.exp import ExperimentSpec, get_preset, make_algorithm
    from repro.exp.algorithm import Bindings
    from repro.exp.runner import (build_bundles, build_graph,
                                  build_optimizer, materialize_data)

    spec = get_preset("churn_ring")
    spec = dataclasses.replace(
        spec, name=f"startup_{scheme}_K{K}",
        clients=ExperimentSpec.uniform_fleet(
            K, aux_heads=spec.clients[0].aux_heads),
        churn=dataclasses.replace(spec.churn, events=()),
        init_scheme=scheme)
    arrays, test_arrays, part = materialize_data(
        spec.data, spec.partition, K)
    bundles = build_bundles(spec)
    algo = make_algorithm(spec)
    t0 = time.time()
    algo.setup(Bindings(
        spec=spec, arrays=arrays, test_arrays=test_arrays, partition=part,
        bundles=bundles, optimizer=build_optimizer(spec),
        graph=build_graph(spec), transport=None,
        num_labels=spec.data.num_labels, local_clients=(0,)))
    wall = time.time() - t0
    inits = len(algo.trainer.initialized_clients)
    return {"name": f"fleet/startup_{scheme}_K{K}", "scheme": scheme,
            "fleet_size": K, "construct_s": round(wall, 3),
            "models_initialized": inits}


def main(scale=None, full: bool = False) -> list:
    from repro.exp import Experiment

    steps = 60 if full else 24
    out, bench_rows = [], []

    for topology in ("complete", "cycle"):
        for rate in ("none", "one", "two"):
            spec = _churn_spec(topology, rate, steps)
            t0 = time.time()
            res = Experiment(spec).run()
            wall = time.time() - t0
            meter = res.trainer.meter
            rec = {
                "name": f"fleet/churn_{topology}_{rate}",
                "topology": topology,
                "churn": rate,
                "steps": steps,
                "wall_s": round(wall, 2),
                "beta_sh": round(res.metrics.get("mean/main/beta_sh",
                                                 float("nan")), 4),
                "tombstoned_bytes": float(meter.tombstoned_bytes),
                "delivered_bytes": float(meter.delivered_bytes),
                "offered_bytes": float(meter.total_bytes),
            }
            out.append(row(rec["name"], wall / steps * 1e6,
                           f"beta_sh={rec['beta_sh']};tombstoned="
                           f"{rec['tombstoned_bytes']:.0f}"))
            bench_rows.append(rec)

    # startup cost: one child process's construction work vs fleet size
    for K in ((4, 8, 12) if full else (4, 8)):
        for scheme in ("legacy", "per_client"):
            rec = _startup_row(K, scheme)
            out.append(row(rec["name"], rec["construct_s"] * 1e6,
                           f"models_initialized="
                           f"{rec['models_initialized']}"))
            bench_rows.append(rec)

    _append_bench_rows(bench_rows)
    return out


if __name__ == "__main__":
    import sys

    sys.path.insert(0, "src")
    for line in main():
        print(line)
