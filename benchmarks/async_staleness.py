"""Async runtime sweep: ``max_staleness`` × client rate-skew.

The async scheduler lets fast clients run ahead of stragglers; the
bounded-staleness gate decides how old a teacher may be before a step
degrades to supervised-only. This benchmark sweeps both knobs on a lossy
ring and reports the trade the ROADMAP's "Async runtime" lever is about:
accuracy (β_sh of the best head) versus wall-clock throughput versus
bytes on the wire.

Each sweep point also appends a row to ``BENCH_async.json`` at the repo
root — {steps/sec, bytes/edge, final acc} — so the perf trajectory
accumulates across PRs.

    PYTHONPATH=src python -m benchmarks.run --only async
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional

from benchmarks.common import BenchScale, best_aux_sh, make_data, row
from repro.comm import CommConfig, SimulatedNetwork
from repro.core import (
    AsyncScheduler,
    MHDConfig,
    DecentralizedTrainer,
    RunConfig,
    ScheduleConfig,
    ScoreboardScheduler,
    cycle_graph,
)
from repro.models.resnet import resnet_tiny
from repro.models.zoo import build_bundle
from repro.optim.optimizers import OptimizerConfig, make_optimizer

_BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_async.json")


def _run_point(scale: BenchScale, data, ticks: int, slow_rate: int,
               max_staleness: Optional[int], s_p: int,
               aux_heads: int = 2) -> Dict[str, float]:
    arrays, test_arrays, part = data
    K = scale.clients
    rates = ScheduleConfig.skewed(K, slow_rate) if slow_rate > 1 else \
        ScheduleConfig.uniform(K)
    bundles = [build_bundle(resnet_tiny(scale.labels,
                                        num_aux_heads=aux_heads))
               for _ in range(K)]
    opt = make_optimizer(OptimizerConfig(init_lr=scale.lr, total_steps=ticks,
                                         grad_clip_norm=scale.grad_clip))
    mhd = MHDConfig(nu_emb=1.0, nu_aux=1.0, num_aux_heads=aux_heads,
                    delta=1, pool_size=2, pool_update_every=s_p)
    net = SimulatedNetwork(latency=1, bandwidth=64 * 1024, drop_prob=0.05,
                           seed=scale.seed,
                           client_rates={i: r for i, r
                                         in enumerate(rates.rates) if r > 1})
    trainer = DecentralizedTrainer(
        bundles, opt, mhd,
        RunConfig(steps=ticks, batch_size=scale.batch_size,
                  public_batch_size=scale.batch_size, eval_every=0,
                  seed=scale.seed, max_staleness=max_staleness),
        arrays, part.client_indices, part.public_indices,
        cycle_graph(K), scale.labels,
        exchange="prediction_topk",
        comm=CommConfig(topk=5, val_dtype="float16", emb_encoding="int8",
                        horizon=s_p * rates.max_rate),
        transport=net)
    sched = AsyncScheduler(trainer, rates)
    t0 = time.time()
    for _ in range(ticks):
        sched.tick()
    wall = time.time() - t0
    ev = trainer.evaluate(test_arrays)
    meter = trainer.meter
    num_edges = max(len(meter.by_edge), 1)
    return {
        "acc": best_aux_sh(ev),
        "steps_per_sec": sum(sched.local_steps) / wall,
        "ticks_per_sec": ticks / wall,
        "bytes_per_edge": meter.total_bytes / num_edges,
        "bytes_total": float(meter.total_bytes),
        "stale_skips": float(sum(meter.gate_stale.values())),
        "local_steps": float(sum(sched.local_steps)),
        "us_per_tick": wall / ticks * 1e6,
    }


def _make_skew_trainer(scale: BenchScale, data, rates: ScheduleConfig,
                       steps_fast: int, s_p: int,
                       aux_heads: int = 2) -> DecentralizedTrainer:
    arrays, _, part = data
    K = scale.clients
    bundles = [build_bundle(resnet_tiny(scale.labels,
                                        num_aux_heads=aux_heads))
               for _ in range(K)]
    opt = make_optimizer(OptimizerConfig(init_lr=scale.lr,
                                         total_steps=steps_fast,
                                         grad_clip_norm=scale.grad_clip))
    mhd = MHDConfig(nu_emb=1.0, nu_aux=1.0, num_aux_heads=aux_heads,
                    delta=1, pool_size=2, pool_update_every=s_p)
    return DecentralizedTrainer(
        bundles, opt, mhd,
        RunConfig(steps=steps_fast, batch_size=scale.batch_size,
                  public_batch_size=scale.batch_size, eval_every=0,
                  seed=scale.seed),
        arrays, part.client_indices, part.public_indices,
        cycle_graph(K), scale.labels,
        exchange="prediction_topk",
        comm=CommConfig(topk=5, val_dtype="float16", emb_encoding="int8",
                        horizon=s_p * rates.max_rate))


def _run_skew_wall(scale: BenchScale, data, steps_fast: int = 16,
                   slow_rate: int = 4,
                   slow_pace_s: float = 4.0) -> Dict[str, float]:
    """Lockstep vs scoreboard *wall clock* at 4x rate skew with a
    real-time paced straggler — the throughput half of the out-of-order
    scheduler's claim (the bitwise-equality half lives in
    tests/test_scheduler.py). Both policies run the same work: fast
    clients take ``steps_fast`` local steps, the straggler a quarter of
    that, and the straggler may not step more often than every
    ``slow_pace_s`` real seconds. Lock-step turns each straggler pace
    gap into a fleet-wide stall; the scoreboard overlaps it, so the
    fast clients' completion wall (their last `Resolve`, read off
    ``sched.resolved_at``) should come in well under the lock-step
    wall."""
    import dataclasses as _dc

    # small batches keep per-step compute well under the straggler's pace
    # (the quantity under test is scheduling stall, not matmul time), and
    # a throwaway warmup run eats the jit compile so neither timed policy
    # pays it
    scale = _dc.replace(scale, batch_size=min(scale.batch_size, 8))
    K = scale.clients
    s_p = max(scale.pool_every // 2, 2)
    pace = tuple([0.0] * (K - 1) + [float(slow_pace_s)])
    slow_steps = steps_fast // slow_rate

    warm_rates = ScheduleConfig.uniform(K)
    warm = AsyncScheduler(
        _make_skew_trainer(scale, data, warm_rates, steps_fast, s_p),
        warm_rates)
    for _ in range(s_p + 1):  # past a pool boundary: distill path compiles
        warm.tick()

    rates = ScheduleConfig.skewed(K, slow_rate, pace_s=pace)
    tr_lock = _make_skew_trainer(scale, data, rates, steps_fast, s_p)
    lock = AsyncScheduler(tr_lock, rates)
    t0 = time.perf_counter()
    for _ in range(steps_fast):
        lock.tick()
    lock_fast_wall = max(lock.resolved_at[:K - 1]) - t0
    lock_wall = time.perf_counter() - t0

    rates_sb = ScheduleConfig.skewed(K, slow_rate, pace_s=pace)
    tr_sb = _make_skew_trainer(scale, data, rates_sb, steps_fast, s_p)
    sb = ScoreboardScheduler(tr_sb, rates_sb)
    targets = tuple([steps_fast] * (K - 1) + [slow_steps])
    t0 = time.perf_counter()
    sb.run_until_steps(targets)
    sb_fast_wall = max(sb.resolved_at[:K - 1]) - t0
    sb_wall = time.perf_counter() - t0

    assert lock.local_steps == sb.local_steps == list(targets)
    total_steps = sum(targets)
    return {
        "lockstep_wall_s": lock_wall,
        "lockstep_fast_wall_s": lock_fast_wall,
        "scoreboard_wall_s": sb_wall,
        "scoreboard_fast_wall_s": sb_fast_wall,
        "fast_wall_ratio": sb_fast_wall / max(lock_fast_wall, 1e-9),
        "lockstep_steps_per_sec": total_steps / lock_wall,
        "scoreboard_steps_per_sec": total_steps / sb_wall,
    }


def _append_bench_rows(rows: List[Dict]) -> None:
    existing: List[Dict] = []
    try:
        with open(_BENCH_JSON) as f:
            existing = json.load(f)
        if not isinstance(existing, list):
            existing = []
    except (OSError, ValueError):
        existing = []
    with open(_BENCH_JSON, "w") as f:
        json.dump(existing + rows, f, indent=1)
        f.write("\n")


def main(scale=None, full: bool = False) -> list:
    scale = scale or BenchScale()
    ticks = min(scale.steps, 400 if full else 150)
    s_p = scale.pool_every
    data = make_data(scale)
    out, bench_rows = [], []
    for slow_rate in (1, 4):
        for ms in (None, 2 * s_p, s_p // 2):
            r = _run_point(scale, data, ticks, slow_rate, ms, s_p)
            name = (f"async/skew{slow_rate}x_ms"
                    f"{'inf' if ms is None else ms}")
            out.append(row(
                name, r["us_per_tick"],
                f"acc={r['acc']:.3f};steps_per_sec={r['steps_per_sec']:.1f};"
                f"bytes_per_edge={r['bytes_per_edge']:.0f};"
                f"stale_skips={r['stale_skips']:.0f}"))
            bench_rows.append({
                "name": name,
                "slow_rate": slow_rate,
                "max_staleness": ms,
                "ticks": ticks,
                "steps_per_sec": round(r["steps_per_sec"], 2),
                "bytes_per_edge": round(r["bytes_per_edge"], 1),
                "final_acc": round(r["acc"], 4),
            })
    # out-of-order scheduling: same work, real-time paced straggler —
    # lockstep stalls the fleet on every straggler pace gap, the
    # scoreboard overlaps it (fast-completion wall via resolved_at)
    w = _run_skew_wall(scale, data)
    out.append(row(
        "async/ooo_skew4x", w["scoreboard_fast_wall_s"] * 1e6,
        f"fast_wall_ratio={w['fast_wall_ratio']:.2f};"
        f"lockstep_wall={w['lockstep_wall_s']:.2f}s;"
        f"sb_fast_wall={w['scoreboard_fast_wall_s']:.2f}s"))
    bench_rows.append({
        "name": "async/scoreboard_vs_lockstep_skew4x",
        "slow_rate": 4,
        "lockstep_wall_s": round(w["lockstep_wall_s"], 3),
        "lockstep_fast_wall_s": round(w["lockstep_fast_wall_s"], 3),
        "scoreboard_wall_s": round(w["scoreboard_wall_s"], 3),
        "scoreboard_fast_wall_s": round(w["scoreboard_fast_wall_s"], 3),
        "fast_wall_ratio": round(w["fast_wall_ratio"], 3),
        "lockstep_steps_per_sec": round(w["lockstep_steps_per_sec"], 2),
        "scoreboard_steps_per_sec": round(w["scoreboard_steps_per_sec"], 2),
    })
    _append_bench_rows(bench_rows)
    return out


if __name__ == "__main__":
    import sys

    sys.path.insert(0, "src")
    for line in main():
        print(line)
