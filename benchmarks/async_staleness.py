"""Async runtime sweep: ``max_staleness`` × client rate-skew.

The async scheduler lets fast clients run ahead of stragglers; the
bounded-staleness gate decides how old a teacher may be before a step
degrades to supervised-only. This benchmark sweeps both knobs on a lossy
ring and reports the trade the ROADMAP's "Async runtime" lever is about:
accuracy (β_sh of the best head) versus wall-clock throughput versus
bytes on the wire.

Each sweep point also appends a row to ``BENCH_async.json`` at the repo
root — {steps/sec, bytes/edge, final acc} — so the perf trajectory
accumulates across PRs.

    PYTHONPATH=src python -m benchmarks.run --only async
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional

from benchmarks.common import BenchScale, best_aux_sh, make_data, row
from repro.comm import CommConfig, SimulatedNetwork
from repro.core import (
    AsyncScheduler,
    MHDConfig,
    DecentralizedTrainer,
    RunConfig,
    ScheduleConfig,
    cycle_graph,
)
from repro.models.resnet import resnet_tiny
from repro.models.zoo import build_bundle
from repro.optim.optimizers import OptimizerConfig, make_optimizer

_BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_async.json")


def _run_point(scale: BenchScale, data, ticks: int, slow_rate: int,
               max_staleness: Optional[int], s_p: int,
               aux_heads: int = 2) -> Dict[str, float]:
    arrays, test_arrays, part = data
    K = scale.clients
    rates = ScheduleConfig.skewed(K, slow_rate) if slow_rate > 1 else \
        ScheduleConfig.uniform(K)
    bundles = [build_bundle(resnet_tiny(scale.labels,
                                        num_aux_heads=aux_heads))
               for _ in range(K)]
    opt = make_optimizer(OptimizerConfig(init_lr=scale.lr, total_steps=ticks,
                                         grad_clip_norm=scale.grad_clip))
    mhd = MHDConfig(nu_emb=1.0, nu_aux=1.0, num_aux_heads=aux_heads,
                    delta=1, pool_size=2, pool_update_every=s_p)
    net = SimulatedNetwork(latency=1, bandwidth=64 * 1024, drop_prob=0.05,
                           seed=scale.seed,
                           client_rates={i: r for i, r
                                         in enumerate(rates.rates) if r > 1})
    trainer = DecentralizedTrainer(
        bundles, opt, mhd,
        RunConfig(steps=ticks, batch_size=scale.batch_size,
                  public_batch_size=scale.batch_size, eval_every=0,
                  seed=scale.seed, max_staleness=max_staleness),
        arrays, part.client_indices, part.public_indices,
        cycle_graph(K), scale.labels,
        exchange="prediction_topk",
        comm=CommConfig(topk=5, val_dtype="float16", emb_encoding="int8",
                        horizon=s_p * rates.max_rate),
        transport=net)
    sched = AsyncScheduler(trainer, rates)
    t0 = time.time()
    for _ in range(ticks):
        sched.tick()
    wall = time.time() - t0
    ev = trainer.evaluate(test_arrays)
    meter = trainer.meter
    num_edges = max(len(meter.by_edge), 1)
    return {
        "acc": best_aux_sh(ev),
        "steps_per_sec": sum(sched.local_steps) / wall,
        "ticks_per_sec": ticks / wall,
        "bytes_per_edge": meter.total_bytes / num_edges,
        "bytes_total": float(meter.total_bytes),
        "stale_skips": float(sum(meter.gate_stale.values())),
        "local_steps": float(sum(sched.local_steps)),
        "us_per_tick": wall / ticks * 1e6,
    }


def _append_bench_rows(rows: List[Dict]) -> None:
    existing: List[Dict] = []
    try:
        with open(_BENCH_JSON) as f:
            existing = json.load(f)
        if not isinstance(existing, list):
            existing = []
    except (OSError, ValueError):
        existing = []
    with open(_BENCH_JSON, "w") as f:
        json.dump(existing + rows, f, indent=1)
        f.write("\n")


def main(scale=None, full: bool = False) -> list:
    scale = scale or BenchScale()
    ticks = min(scale.steps, 400 if full else 150)
    s_p = scale.pool_every
    data = make_data(scale)
    out, bench_rows = [], []
    for slow_rate in (1, 4):
        for ms in (None, 2 * s_p, s_p // 2):
            r = _run_point(scale, data, ticks, slow_rate, ms, s_p)
            name = (f"async/skew{slow_rate}x_ms"
                    f"{'inf' if ms is None else ms}")
            out.append(row(
                name, r["us_per_tick"],
                f"acc={r['acc']:.3f};steps_per_sec={r['steps_per_sec']:.1f};"
                f"bytes_per_edge={r['bytes_per_edge']:.0f};"
                f"stale_skips={r['stale_skips']:.0f}"))
            bench_rows.append({
                "name": name,
                "slow_rate": slow_rate,
                "max_staleness": ms,
                "ticks": ticks,
                "steps_per_sec": round(r["steps_per_sec"], 2),
                "bytes_per_edge": round(r["bytes_per_edge"], 1),
                "final_acc": round(r["acc"], 4),
            })
    _append_bench_rows(bench_rows)
    return out


if __name__ == "__main__":
    import sys

    sys.path.insert(0, "src")
    for line in main():
        print(line)
