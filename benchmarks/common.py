"""Shared experiment harness for the paper-table benchmarks.

Every benchmark reproduces one paper table/figure *qualitatively* at CPU
scale (DESIGN.md §7.1): same protocol (partition skew s, γ_pub, checkpoint
pools, confidence gating), synthetic class-conditional data, tiny ResNets.
The reported numbers are orderings/deltas, not ImageNet absolutes.

Output contract (benchmarks/run.py): each experiment prints
``name,us_per_call,derived`` CSV rows, where us_per_call is the mean
wall-time per training step and derived is the headline metric.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import (
    MHDConfig,
    DecentralizedTrainer,
    RunConfig,
    complete_graph,
    cycle_graph,
    islands_graph,
)
from repro.core.supervised import eval_per_label_accuracy, train_supervised
from repro.data import PartitionConfig, make_synthetic_vision, partition_dataset
from repro.models.resnet import resnet_tiny, resnet_tiny34
from repro.models.zoo import build_bundle
from repro.optim.optimizers import OptimizerConfig, make_optimizer


@dataclasses.dataclass
class BenchScale:
    """CPU-scale stand-ins for the paper's 8-client/250-label ImageNet runs."""

    clients: int = 4
    labels: int = 16
    labels_per_client: int = 4
    samples_per_label: int = 200
    image_size: int = 8
    noise: float = 2.0
    steps: int = 600
    batch_size: int = 32
    lr: float = 0.05
    grad_clip: float = 1.0
    seed: int = 0
    gamma_pub: float = 0.1
    skew: float = 100.0
    pool_every: int = 10


# Calibration notes (EXPERIMENTS.md §Repro-notes): at 16-way/CPU scale the
# paper's ν_aux=3 (tuned for 1000-way ImageNet CE) over-weights distillation
# gradients; ν_aux=1 with global-norm clipping reproduces the paper's
# orderings. The confidence-gating oracle in this regime selects a correct
# teacher on 91% of test samples (vs 53% single-client accuracy).
QUICK = BenchScale()
FULL = BenchScale(clients=6, labels=20, labels_per_client=5,
                  samples_per_label=300, steps=1200)


def make_data(scale: BenchScale, gamma_pub: Optional[float] = None,
              skew: Optional[float] = None):
    ds = make_synthetic_vision(
        num_labels=scale.labels, samples_per_label=scale.samples_per_label,
        image_size=scale.image_size, noise=scale.noise, seed=scale.seed)
    test = make_synthetic_vision(
        num_labels=scale.labels, samples_per_label=15,
        image_size=scale.image_size, noise=scale.noise,
        seed=scale.seed + 991, prototype_seed=scale.seed)
    pcfg = PartitionConfig(
        num_clients=scale.clients, num_labels=scale.labels,
        labels_per_client=scale.labels_per_client, assignment="random",
        skew=scale.skew if skew is None else skew,
        gamma_pub=scale.gamma_pub if gamma_pub is None else gamma_pub,
        seed=scale.seed)
    part = partition_dataset(ds.labels, pcfg)
    arrays = {"images": ds.images, "labels": ds.labels}
    test_arrays = {"images": test.images, "labels": test.labels}
    return arrays, test_arrays, part


def run_mhd(scale: BenchScale, *, aux_heads: int = 3, nu_emb: float = 1.0,
            nu_aux: float = 1.0, delta: int = 1, confidence: str = "max",
            use_sl: bool = False, use_sf: bool = False,
            skip_confident: bool = False, topology: str = "complete",
            skew: Optional[float] = None, gamma_pub: Optional[float] = None,
            bundles=None, steps: Optional[int] = None,
            data=None) -> Dict[str, float]:
    """One MHD run; returns eval metrics + '_step_us' wall time per step."""
    arrays, test_arrays, part = data or make_data(scale, gamma_pub, skew)
    K = scale.clients
    graph = {"complete": complete_graph(K),
             "cycle": cycle_graph(K),
             "islands": islands_graph(K, 2)}[topology]
    if bundles is None:
        bundles = [build_bundle(resnet_tiny(scale.labels,
                                            num_aux_heads=aux_heads))
                   for _ in range(K)]
    steps = steps or scale.steps
    opt = make_optimizer(OptimizerConfig(init_lr=scale.lr, total_steps=steps,
                                         grad_clip_norm=scale.grad_clip))
    mhd = MHDConfig(nu_emb=nu_emb, nu_aux=nu_aux, num_aux_heads=aux_heads,
                    delta=delta, confidence=confidence, use_self=use_sf,
                    use_same_level=use_sl,
                    skip_when_student_confident=skip_confident,
                    pool_size=min(K, 8), pool_update_every=scale.pool_every)
    trainer = DecentralizedTrainer(
        bundles, opt, mhd,
        RunConfig(steps=steps, batch_size=scale.batch_size,
                  public_batch_size=scale.batch_size, eval_every=0,
                  seed=scale.seed),
        arrays, part.client_indices, part.public_indices, graph, scale.labels)
    t0 = time.time()
    for t in range(steps):
        trainer.step(t)
    per_step = (time.time() - t0) / steps
    ev = trainer.evaluate(test_arrays)
    ev["_step_us"] = per_step * 1e6
    ev["_trainer"] = trainer  # for per-client drill-downs (topology bench)
    return ev


def run_separate(scale: BenchScale, *, aux_heads: int = 0,
                 data=None) -> Dict[str, float]:
    """Paper 'Separate': each client trains alone on its private shard."""
    arrays, test_arrays, part = data or make_data(scale)
    opt = make_optimizer(OptimizerConfig(init_lr=scale.lr,
                                         total_steps=scale.steps,
                                         grad_clip_norm=scale.grad_clip))
    accs_sh, accs_priv = [], []
    t0 = time.time()
    for i in range(scale.clients):
        bundle = build_bundle(resnet_tiny(scale.labels))
        params = train_supervised(bundle, opt, arrays,
                                  part.client_indices[i], steps=scale.steps,
                                  batch_size=scale.batch_size,
                                  seed=scale.seed + i)
        per_label, present = eval_per_label_accuracy(
            bundle, params, test_arrays, scale.labels)
        hist = np.bincount(arrays["labels"][part.client_indices[i]],
                           minlength=scale.labels).astype(float)
        hist /= hist.sum()
        accs_sh.append(per_label[present].mean())
        accs_priv.append((per_label * hist).sum())
    per_step = (time.time() - t0) / (scale.steps * scale.clients)
    return {"mean/main/beta_sh": float(np.mean(accs_sh)),
            "mean/main/beta_priv": float(np.mean(accs_priv)),
            "_step_us": per_step * 1e6}


def run_fedavg_baseline(scale: BenchScale, average_every: int = 20,
                        data=None) -> Dict[str, float]:
    from repro.core.fedavg import train_fedavg

    arrays, test_arrays, part = data or make_data(scale)
    bundle = build_bundle(resnet_tiny(scale.labels))
    opt = make_optimizer(OptimizerConfig(init_lr=scale.lr,
                                         total_steps=scale.steps,
                                         grad_clip_norm=scale.grad_clip))
    t0 = time.time()
    params = train_fedavg(bundle, opt, arrays, part.client_indices,
                          steps=scale.steps, batch_size=scale.batch_size,
                          average_every=average_every, seed=scale.seed)
    per_step = (time.time() - t0) / (scale.steps * scale.clients)
    per_label, present = eval_per_label_accuracy(bundle, params, test_arrays,
                                                 scale.labels)
    return {"mean/main/beta_sh": float(per_label[present].mean()),
            "_step_us": per_step * 1e6}


def run_supervised_baseline(scale: BenchScale, data=None) -> Dict[str, float]:
    arrays, test_arrays, part = data or make_data(scale)
    bundle = build_bundle(resnet_tiny(scale.labels))
    opt = make_optimizer(OptimizerConfig(init_lr=scale.lr,
                                         total_steps=scale.steps,
                                         grad_clip_norm=scale.grad_clip))
    all_private = np.concatenate(part.client_indices)
    t0 = time.time()
    params = train_supervised(bundle, opt, arrays, all_private,
                              steps=scale.steps,
                              batch_size=scale.batch_size, seed=scale.seed)
    per_step = (time.time() - t0) / scale.steps
    per_label, present = eval_per_label_accuracy(bundle, params, test_arrays,
                                                 scale.labels)
    return {"mean/main/beta_sh": float(per_label[present].mean()),
            "_step_us": per_step * 1e6}


def best_aux_sh(ev: Dict[str, float]) -> float:
    """Max shared accuracy over heads (the paper reports the best aux)."""
    vals = [v for k, v in ev.items()
            if k.startswith("mean/") and k.endswith("/beta_sh")]
    return max(vals)


def row(name: str, us: float, derived) -> str:
    return f"{name},{us:.0f},{derived}"
