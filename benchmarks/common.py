"""Shared experiment harness for the paper-table benchmarks.

Every benchmark reproduces one paper table/figure *qualitatively* at CPU
scale (DESIGN.md §7.1): same protocol (partition skew s, γ_pub, checkpoint
pools, confidence gating), synthetic class-conditional data, tiny ResNets.
The reported numbers are orderings/deltas, not ImageNet absolutes.

All runs go through the declarative `repro.exp` Experiment API: each
``run_*`` helper builds an `ExperimentSpec` from a `BenchScale` and calls
`Experiment.run()` — no hand-rolled trainer wiring. Helpers return plain
JSON-serializable metric dicts; benchmarks that need live-object
drill-downs (per-client params for hop accuracy) use ``run_mhd_result``
and read ``result.trainer`` out-of-band.

Output contract (benchmarks/run.py): each experiment prints
``name,us_per_call,derived`` CSV rows, where us_per_call is the mean
wall-time per training step and derived is the headline metric.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.exp import (
    AlgorithmSpec,
    ClientSpec,
    DataSpec,
    Experiment,
    ExperimentResult,
    ExperimentSpec,
    OptimizerSpec,
    PartitionSpec,
    TopologySpec,
    TrainSpec,
    materialize_data,
)


@dataclasses.dataclass
class BenchScale:
    """CPU-scale stand-ins for the paper's 8-client/250-label ImageNet runs."""

    clients: int = 4
    labels: int = 16
    labels_per_client: int = 4
    samples_per_label: int = 200
    image_size: int = 8
    noise: float = 2.0
    steps: int = 600
    batch_size: int = 32
    lr: float = 0.05
    grad_clip: float = 1.0
    seed: int = 0
    gamma_pub: float = 0.1
    skew: float = 100.0
    pool_every: int = 10


# Calibration notes (EXPERIMENTS.md §Repro-notes): at 16-way/CPU scale the
# paper's ν_aux=3 (tuned for 1000-way ImageNet CE) over-weights distillation
# gradients; ν_aux=1 with global-norm clipping reproduces the paper's
# orderings. The confidence-gating oracle in this regime selects a correct
# teacher on 91% of test samples (vs 53% single-client accuracy).
QUICK = BenchScale()
FULL = BenchScale(clients=6, labels=20, labels_per_client=5,
                  samples_per_label=300, steps=1200)


def base_spec(scale: BenchScale, algorithm: AlgorithmSpec, *,
              clients: Optional[Sequence[ClientSpec]] = None,
              gamma_pub: Optional[float] = None,
              skew: Optional[float] = None,
              topology: str = "complete",
              steps: Optional[int] = None) -> ExperimentSpec:
    """The one place a `BenchScale` becomes an `ExperimentSpec`."""
    steps = steps or scale.steps
    return ExperimentSpec(
        name=f"bench_{algorithm.name}",
        algorithm=algorithm,
        data=DataSpec(num_labels=scale.labels,
                      samples_per_label=scale.samples_per_label,
                      image_size=scale.image_size, noise=scale.noise,
                      seed=scale.seed),
        partition=PartitionSpec(
            labels_per_client=scale.labels_per_client, assignment="random",
            skew=scale.skew if skew is None else skew,
            gamma_pub=scale.gamma_pub if gamma_pub is None else gamma_pub),
        clients=tuple(clients) if clients is not None
        else ExperimentSpec.uniform_fleet(scale.clients),
        topology=TopologySpec(topology, islands=2),
        optimizer=OptimizerSpec(init_lr=scale.lr, total_steps=steps,
                                grad_clip_norm=scale.grad_clip),
        train=TrainSpec(steps=steps, batch_size=scale.batch_size,
                        public_batch_size=scale.batch_size,
                        seed=scale.seed))


def make_data(scale: BenchScale, gamma_pub: Optional[float] = None,
              skew: Optional[float] = None):
    """Pre-built data triple, shared across runs for comparability."""
    spec = base_spec(scale, AlgorithmSpec("supervised"),
                     gamma_pub=gamma_pub, skew=skew)
    return materialize_data(spec.data, spec.partition, spec.num_clients)


def run_mhd_result(scale: BenchScale, *, aux_heads: int = 3,
                   nu_emb: float = 1.0, nu_aux: float = 1.0, delta: int = 1,
                   confidence: str = "max", use_sl: bool = False,
                   use_sf: bool = False, skip_confident: bool = False,
                   topology: str = "complete", skew: Optional[float] = None,
                   gamma_pub: Optional[float] = None,
                   clients: Optional[Sequence[ClientSpec]] = None,
                   steps: Optional[int] = None,
                   data=None) -> ExperimentResult:
    """One MHD run; the full result (live trainer rides out-of-band)."""
    if clients is None:
        clients = ExperimentSpec.uniform_fleet(scale.clients,
                                               aux_heads=aux_heads)
    algo = AlgorithmSpec("mhd", {
        "nu_emb": nu_emb, "nu_aux": nu_aux, "num_aux_heads": aux_heads,
        "delta": delta, "confidence": confidence, "use_self": use_sf,
        "use_same_level": use_sl,
        "skip_when_student_confident": skip_confident,
        "pool_size": min(scale.clients, 8),
        "pool_update_every": scale.pool_every})
    spec = base_spec(scale, algo, clients=clients, gamma_pub=gamma_pub,
                     skew=skew, topology=topology, steps=steps)
    return Experiment(spec, data=data).run()


def run_mhd(scale: BenchScale, **kw) -> Dict[str, float]:
    """One MHD run; returns eval metrics + '_step_us' wall time per step."""
    res = run_mhd_result(scale, **kw)
    ev = dict(res.metrics)
    ev["_step_us"] = res.us_per_step
    return ev


def run_separate(scale: BenchScale, *, aux_heads: int = 0,
                 skew: Optional[float] = None,
                 gamma_pub: Optional[float] = None,
                 data=None) -> Dict[str, float]:
    """Paper 'Separate': each client trains alone on its private shard."""
    spec = base_spec(
        scale, AlgorithmSpec("supervised", {"scope": "separate"}),
        clients=ExperimentSpec.uniform_fleet(scale.clients,
                                             aux_heads=aux_heads),
        skew=skew, gamma_pub=gamma_pub)
    res = Experiment(spec, data=data).run()
    ev = dict(res.metrics)
    ev["_step_us"] = res.us_per_step / scale.clients
    return ev


def run_fedmd(scale: BenchScale, *, digest_weight: float = 1.0,
              clients: Optional[Sequence[ClientSpec]] = None,
              skew: Optional[float] = None,
              gamma_pub: Optional[float] = None,
              data=None) -> Dict[str, float]:
    """FedMD (centralized consensus distillation, Table 2 comparison)."""
    spec = base_spec(
        scale, AlgorithmSpec("fedmd", {"digest_weight": digest_weight}),
        clients=clients, skew=skew, gamma_pub=gamma_pub)
    res = Experiment(spec, data=data).run()
    ev = dict(res.metrics)
    ev["_step_us"] = res.us_per_step / scale.clients
    return ev


def run_fedavg_baseline(scale: BenchScale, average_every: int = 20,
                        skew: Optional[float] = None,
                        gamma_pub: Optional[float] = None,
                        data=None) -> Dict[str, float]:
    spec = base_spec(
        scale, AlgorithmSpec("fedavg", {"average_every": average_every}),
        skew=skew, gamma_pub=gamma_pub)
    res = Experiment(spec, data=data).run()
    ev = dict(res.metrics)
    ev["_step_us"] = res.us_per_step / scale.clients
    return ev


def run_supervised_baseline(scale: BenchScale,
                            skew: Optional[float] = None,
                            gamma_pub: Optional[float] = None,
                            data=None) -> Dict[str, float]:
    spec = base_spec(scale, AlgorithmSpec("supervised", {"scope": "pooled"}),
                     skew=skew, gamma_pub=gamma_pub)
    res = Experiment(spec, data=data).run()
    ev = dict(res.metrics)
    ev["_step_us"] = res.us_per_step
    return ev


def client_beta_sh(ev: Dict[str, float], num_clients: int,
                   head: str = "main") -> List[float]:
    """Per-client shared accuracies out of the unified metric namespace."""
    return [ev[f"c{i}/{head}/beta_sh"] for i in range(num_clients)]


def best_aux_sh(ev: Dict[str, float]) -> float:
    """Max shared accuracy over heads (the paper reports the best aux)."""
    vals = [v for k, v in ev.items()
            if k.startswith("mean/") and k.endswith("/beta_sh")]
    return max(vals)


def row(name: str, us: float, derived) -> str:
    return f"{name},{us:.0f},{derived}"
