"""Heterogeneous-architecture LM distillation fleet (repro.lm) — appended
to ``BENCH_lm.json`` at the repo root.

Two runs of the ``lm_hetero`` preset sharing one data triple:

  * ``mhd`` — the SSM + dense transformer + MoE fleet co-training on the
    entropy-adaptive, delta-compressed prediction wire (complete graph);
  * ``isolated`` — the same three clients on an isolated topology: no
    in-neighbors, so every step is supervised-only (the paper's
    'Separate' baseline, LM edition).

The headline row reports the per-client aggregated-distribution gain
(β_sh averaged over the client's heads, mhd − isolated) *at the
measured bytes/token* — the budget ledger the adaptive codec optimizes
under. The head mean is the right aggregate here: the supervised main
head only feels the fleet through the shared trunk, while the aux
chain is what distills the neighbors' domains (the paper's Fig. 4
reads accuracy off the deeper heads for the same reason) — the
per-head breakdown stays in the row.

    PYTHONPATH=src python -m benchmarks.run --only lm
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List

from benchmarks.common import row

_BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_lm.json")


def _append_bench_rows(rows: List[Dict]) -> None:
    existing: List[Dict] = []
    try:
        with open(_BENCH_JSON) as f:
            existing = json.load(f)
        if not isinstance(existing, list):
            existing = []
    except (OSError, ValueError):
        existing = []
    with open(_BENCH_JSON, "w") as f:
        json.dump(existing + rows, f, indent=1)
        f.write("\n")


def main(scale=None, full: bool = False) -> list:
    from repro.exp import Experiment, TopologySpec, get_preset, \
        materialize_data
    from repro.lm import lm_wire_tokens

    # long enough for the teachers to know their own domains — gains
    # over isolated training are noise before ~100 steps at this scale
    steps = 300 if full else 150
    base = get_preset("lm_hetero")
    base = dataclasses.replace(
        base,
        transport=dataclasses.replace(base.transport, kind="loopback"),
        train=dataclasses.replace(base.train, steps=steps))
    # one shared data triple: both runs see identical domains/partition
    data = materialize_data(base.data, base.partition, base.num_clients)

    t0 = time.time()
    res_mhd = Experiment(base, data=data).run()
    mhd_wall = time.time() - t0
    meter = res_mhd.trainer.meter

    iso = dataclasses.replace(base, name="lm_hetero_isolated",
                              topology=TopologySpec("isolated"))
    t0 = time.time()
    res_iso = Experiment(iso, data=data).run()
    iso_wall = time.time() - t0

    # bytes/token of the offered wire: every message carries
    # horizon windows x lm_wire_tokens tokens
    tokens_per_msg = base.wire.horizon * lm_wire_tokens(
        base.train.public_batch_size, base.data.seq_len,
        base.data.max_positions)
    n_msgs = max(meter.num_messages, 1)
    bytes_per_token = meter.total_bytes / (n_msgs * tokens_per_msg)

    clients = []
    gains = []
    for i, c in enumerate(base.clients):
        heads = ["main"] + [f"aux{h}" for h in range(1, c.aux_heads + 1)]
        per_head = {h: {"mhd": res_mhd.metrics[f"c{i}/{h}/beta_sh"],
                        "isolated": res_iso.metrics[f"c{i}/{h}/beta_sh"]}
                    for h in heads}
        b_mhd = sum(v["mhd"] for v in per_head.values()) / len(heads)
        b_iso = sum(v["isolated"] for v in per_head.values()) / len(heads)
        gains.append(b_mhd - b_iso)
        clients.append({
            "client": i, "arch": c.arch,
            "beta_sh_mhd": round(b_mhd, 4),
            "beta_sh_isolated": round(b_iso, 4),
            "gain": round(b_mhd - b_iso, 4),
            "heads": {h: {k: round(v, 4) for k, v in hv.items()}
                      for h, hv in per_head.items()}})

    bench = {
        "name": "lm/hetero_fleet",
        "preset": "lm_hetero",
        "steps": steps,
        "archs": [c.arch for c in base.clients],
        "budget_bytes_per_token": base.wire.budget_bytes_per_token,
        "compression": base.wire.compression,
        "measured_bytes_per_token": round(bytes_per_token, 2),
        "offered_bytes": int(meter.total_bytes),
        "delivered_bytes": int(meter.delivered_bytes),
        "mean_gain_beta_sh": round(sum(gains) / len(gains), 4),
        "clients": clients,
        "wall_s_mhd": round(mhd_wall, 2),
        "wall_s_isolated": round(iso_wall, 2),
    }
    _append_bench_rows([bench])

    out = [row("lm/hetero_fleet", mhd_wall / steps * 1e6,
               f"mean_gain={bench['mean_gain_beta_sh']};"
               f"bytes_per_token={bench['measured_bytes_per_token']};"
               f"budget={base.wire.budget_bytes_per_token}")]
    for c in clients:
        out.append(row(f"lm/{c['arch']}", 0,
                       f"mhd={c['beta_sh_mhd']};"
                       f"isolated={c['beta_sh_isolated']};"
                       f"gain={c['gain']}"))
    return out


if __name__ == "__main__":
    import sys

    sys.path.insert(0, "src")
    for line in main():
        print(line)
