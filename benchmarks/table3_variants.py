"""Paper Table 3 (App. B.1): distillation-target variants — Base, SL
(same-level targets), SF (self target), SL+SF, Δ=2, All."""
from __future__ import annotations

from benchmarks.common import best_aux_sh, make_data, row, run_mhd


def main(scale, full: bool = False) -> list:
    rows = []
    data = make_data(scale, skew=100.0)
    variants = [
        ("base", dict()),
        ("delta2", dict(delta=2)),
        ("SL", dict(use_sl=True)),
        ("SF", dict(use_sf=True)),
        ("SL+SF", dict(use_sl=True, use_sf=True)),
        ("all", dict(use_sl=True, use_sf=True, delta=2)),
    ]
    if not full:
        variants = [v for v in variants if v[0] in ("base", "delta2", "all")]
    for name, kw in variants:
        ev = run_mhd(scale, aux_heads=3, skew=100.0, data=data, **kw)
        derived = (f"variant={name};"
                   f"main_priv={ev['mean/main/beta_priv']:.3f};"
                   f"best_sh={best_aux_sh(ev):.3f}")
        rows.append(row("table3/variants", ev["_step_us"], derived))
    return rows
