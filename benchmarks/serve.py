"""Serving benchmark (repro.serve) — appended to ``BENCH_serve.json``.

Four measurements:

  * **requests/sec + p50/p99 latency vs fleet size** — a mixed
    classify/teacher stream against fronts of K personalized models
    (fresh-init params: routing/caching/latency do not depend on
    training, so the sweep stays cheap).
  * **teacher-cache hit rate** — same stream, hot-window reuse pattern.
  * **continuous vs static batching** — the same mixed-generation-length
    request set through the same engine under both admission policies;
    continuous must win on wall time (static drains the batch to the
    longest request before admitting more).
  * **serve→distill feedback** — the full `run_serve_scenario` loop
    (train → snapshot → serve → distill from served traffic over the
    metered wire): the row reports how many client-steps distilled from
    production traffic and the wire bytes they cost.

``--smoke`` is the CI gate: a bounded run (small arch, 8 requests) that
asserts every request completes and the teacher cache actually hits on
repeated prompts.

    PYTHONPATH=src python -m benchmarks.run --only serve
    PYTHONPATH=src python -m benchmarks.serve --smoke
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import tempfile
import time
from typing import Dict, List

from benchmarks.common import row

_BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_serve.json")


def _append_bench_rows(rows: List[Dict]) -> None:
    existing: List[Dict] = []
    try:
        with open(_BENCH_JSON) as f:
            existing = json.load(f)
        if not isinstance(existing, list):
            existing = []
    except (OSError, ValueError):
        existing = []
    with open(_BENCH_JSON, "w") as f:
        json.dump(existing + rows, f, indent=1)
        f.write("\n")


# -- fleet-size sweep ---------------------------------------------------------


def _fresh_front(num_clients: int, seed: int = 0):
    """A front over K fresh-init personalized models — serving latency,
    routing, and caching are training-independent, so the fleet-size
    sweep skips the (expensive) gossip run."""
    import jax

    from repro.data.pipeline import PublicPool
    from repro.exp import (AlgorithmSpec, DataSpec, ExperimentSpec,
                           PartitionSpec, TrainSpec, build_bundles,
                           materialize_data)
    from repro.serve import Router, ServeFront, TeacherPredictionCache

    spec = ExperimentSpec(
        name=f"serve_bench_k{num_clients}",
        algorithm=AlgorithmSpec("mhd"),
        data=DataSpec(num_labels=12, samples_per_label=40, seed=seed),
        partition=PartitionSpec(labels_per_client=3, gamma_pub=0.1),
        clients=ExperimentSpec.uniform_fleet(num_clients, aux_heads=2),
        train=TrainSpec(steps=1, batch_size=16, public_batch_size=16,
                        seed=seed))
    arrays, test_arrays, part = materialize_data(
        spec.data, spec.partition, spec.num_clients)
    bundles = build_bundles(spec)
    params = [b.init(jax.random.fold_in(jax.random.PRNGKey(seed), i))
              for i, b in enumerate(bundles)]
    router = Router.from_partition(part, arrays["labels"],
                                   spec.data.num_labels)
    public = PublicPool(arrays, part.public_indices, 16, seed=seed)
    front = ServeFront(bundles, params, router, public,
                       cache=TeacherPredictionCache(8), log_traffic=False)
    return front, test_arrays


def _serve_stream(front, test_arrays, requests: int, seed: int = 0):
    import numpy as np

    from repro.serve import ServeRequest

    rng = np.random.default_rng(seed)
    images, labels = test_arrays["images"], test_arrays["labels"]
    hot_windows = max(2, requests // 8)
    responses = []
    teacher_queries = 0
    t0 = time.perf_counter()
    for rid in range(requests):
        if rid % 3 == 2:
            req = ServeRequest(request_id=rid, kind="teacher",
                               window_id=teacher_queries % hot_windows)
            teacher_queries += 1
        else:
            i = int(rng.integers(0, images.shape[0]))
            req = ServeRequest(request_id=rid, kind="classify",
                               image=images[i], label_hint=int(labels[i]))
        responses.append(front.serve(req))
    wall = time.perf_counter() - t0
    lat = sorted(r.latency_s for r in responses)
    return {"wall_s": wall,
            "rps": len(responses) / max(wall, 1e-9),
            "p50_ms": lat[len(lat) // 2] * 1e3,
            "p99_ms": lat[min(len(lat) - 1, int(len(lat) * 0.99))] * 1e3,
            "hit_rate": front.cache.ledger.hit_rate()}


def _fleet_sweep(fleet_sizes, requests: int):
    out = []
    for k in fleet_sizes:
        front, test_arrays = _fresh_front(k)
        # warm the jits so the sweep measures serving, not compilation
        _serve_stream(front, test_arrays, requests=6, seed=99)
        front.cache.ledger.__init__()
        m = _serve_stream(front, test_arrays, requests=requests)
        m["fleet_size"] = k
        out.append(m)
    return out


# -- continuous vs static batching --------------------------------------------


def _engine_bench(admission: str, num_slots: int = 4,
                  max_new_tokens: int = 16, seed: int = 0):
    import jax
    import numpy as np

    from repro.configs import get_reduced
    from repro.models.zoo import build_bundle
    from repro.serve import ContinuousBatchingEngine, ServeRequest

    cfg = get_reduced("minitron-4b")
    bundle = build_bundle(cfg)
    params = bundle.init(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    engine = ContinuousBatchingEngine(
        bundle, params, num_slots=num_slots,
        cache_len=8 + max_new_tokens, admission=admission)
    # mixed lengths: 1..max_new tokens — the distribution static batching
    # serializes (every batch drains to its longest member)
    for rid in range(num_slots * 3):
        engine.submit(ServeRequest(
            request_id=rid, kind="generate",
            prompt=rng.integers(0, cfg.vocab_size, size=int(
                rng.integers(4, 9)), dtype=np.int32),
            max_new_tokens=int(rng.integers(1, max_new_tokens + 1))))
    t0 = time.perf_counter()
    responses = engine.run()
    wall = time.perf_counter() - t0
    tokens = sum(len(r.tokens) for r in responses)
    return {"admission": admission, "wall_s": wall,
            "requests": len(responses), "tokens": tokens,
            "tokens_per_s": tokens / max(wall, 1e-9),
            "ticks": engine.ticks, "occupancy": engine.occupancy()}


# -- the feedback loop --------------------------------------------------------


def _feedback_spec(full: bool):
    from repro.exp import get_preset

    spec = get_preset("serve_loop")
    if not full:
        spec = dataclasses.replace(
            spec, train=dataclasses.replace(spec.train, steps=20),
            serve=dataclasses.replace(spec.serve, requests=18,
                                      max_new_tokens=8))
    return spec


def _run_feedback_loop(full: bool) -> Dict[str, float]:
    from repro.serve import run_serve_scenario

    with tempfile.TemporaryDirectory(prefix="bench_serve_") as workdir:
        out = run_serve_scenario(_feedback_spec(full), workdir)
    return out.metrics


# -- orchestrator entry -------------------------------------------------------


def main(scale=None, full: bool = False) -> list:
    fleet_sizes = (2, 4, 8) if full else (2, 4)
    requests = 96 if full else 48
    rows = []
    bench_rows: List[Dict] = []

    for m in _fleet_sweep(fleet_sizes, requests):
        k = m["fleet_size"]
        rows.append(row(
            f"serve_front_k{k}", m["p50_ms"] * 1e3,
            f"rps={m['rps']:.1f} p99_ms={m['p99_ms']:.2f} "
            f"hit_rate={m['hit_rate']:.2f}"))
        bench_rows.append({"kind": "front", **m})

    static = _engine_bench("static")
    cont = _engine_bench("continuous")
    speedup = static["wall_s"] / max(cont["wall_s"], 1e-9)
    for m in (cont, static):
        rows.append(row(
            f"serve_batch_{m['admission']}",
            m["wall_s"] / max(m["tokens"], 1) * 1e6,
            f"tok_s={m['tokens_per_s']:.1f} ticks={m['ticks']} "
            f"occupancy={m['occupancy']:.2f}"))
    rows.append(row("serve_batch_speedup", 0,
                    f"continuous_over_static={speedup:.2f}x"))
    bench_rows.append({"kind": "batching", "continuous": cont,
                       "static": static, "speedup": speedup})

    fb = _run_feedback_loop(full)
    rows.append(row(
        "serve_feedback_loop", fb["serve/p50_ms"] * 1e3,
        f"rps={fb['serve/requests_per_s']:.1f} "
        f"hit_rate={fb['cache/hit_rate']:.2f} "
        f"distill_steps={fb.get('feedback/distill_steps', 0):.0f} "
        f"wire_bytes={fb.get('feedback/wire_bytes', 0):.0f}"))
    bench_rows.append({"kind": "feedback_loop", **fb})

    _append_bench_rows(bench_rows)
    return rows


# -- CI smoke -----------------------------------------------------------------


def smoke() -> int:
    """Bounded serve gate (scripts/check.sh + ci.yml): a tiny fleet, 8
    mixed requests with repeated teacher windows, the minitron engine,
    and one feedback step. Asserts every request completes, the cache
    hits on the repeats, and at least one client distilled from the
    served traffic over the metered wire."""
    import dataclasses as dc

    from repro.exp import get_preset
    from repro.serve import run_serve_scenario

    spec = get_preset("serve_loop")
    spec = dc.replace(
        spec,
        train=dc.replace(spec.train, steps=10),
        serve=dc.replace(spec.serve, requests=8, max_new_tokens=4,
                         num_slots=2, cache_windows=2, feedback_steps=1))
    with tempfile.TemporaryDirectory(prefix="serve_smoke_") as workdir:
        out = run_serve_scenario(spec, workdir)
    m = out.metrics
    served = sum(m[f"served/{k}"] for k in ("classify", "teacher",
                                            "generate"))
    generate_expected = max(spec.serve.num_slots * 2, 4)
    expected = spec.serve.requests + generate_expected
    assert len(out.responses) == expected, \
        f"{len(out.responses)} responses for {expected} requests"
    assert served == expected, f"served {served} of {expected}"
    assert all(r.tokens for r in out.responses
               if r.kind == "generate"), "empty generation"
    assert m["cache/hit_rate"] > 0, \
        f"no cache hits on repeated windows: {m}"
    assert m.get("feedback/distill_steps", 0) >= 1, \
        f"nobody distilled from served traffic: {m}"
    assert m.get("feedback/wire_bytes", 0) > 0, \
        "feedback moved no bytes over the wire"
    print(f"serve smoke OK: {expected} requests served, "
          f"hit_rate={m['cache/hit_rate']:.2f}, "
          f"distill_steps={m['feedback/distill_steps']:.0f}, "
          f"wire_bytes={m['feedback/wire_bytes']:.0f}")
    return 0


if __name__ == "__main__":
    if "--smoke" in sys.argv[1:]:
        sys.exit(smoke())
    print("name,us_per_call,derived")
    for r in main(None, "--full" in sys.argv[1:]):
        print(r, flush=True)
